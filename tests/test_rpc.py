"""RPC protocol integration tests: all four method types, batch pipelining,
futures, cursors, deadlines, ownership, transports."""
import time
import uuid

import numpy as np
import pytest

from repro.core import types as T, wire
from repro.core.schema import MethodDef, ServiceDef
from repro.core.rpc import (Channel, Deadline, Router, RpcError, Server,
                            Status, TcpTransport, connected_pair)
from repro.core.rpc import wire_types as W

Req = T.Struct("Req", [T.Field("x", T.INT32)])
Res = T.Struct("Res", [T.Field("y", T.INT32)])

SVC = ServiceDef("Math", [
    MethodDef("Double", Req, Res),
    MethodDef("CountTo", Req, Res, server_stream=True),
    MethodDef("Sum", Req, Res, client_stream=True),
    MethodDef("Echo", Req, Res, client_stream=True, server_stream=True),
    MethodDef("Fail", Req, Res),
    MethodDef("Slow", Req, Res),
])


class Impl:
    def Double(self, req, ctx):
        return {"y": req["x"] * 2}

    def CountTo(self, req, ctx):
        for i in range(int(ctx.cursor), req["x"]):
            ctx.set_cursor(i + 1)
            yield {"y": i}

    def Sum(self, reqs, ctx):
        return {"y": sum(r["x"] for r in reqs)}

    def Echo(self, reqs, ctx):
        for r in reqs:
            yield {"y": r["x"]}

    def Fail(self, req, ctx):
        raise RpcError(Status.NOT_FOUND, "nope")

    def Slow(self, req, ctx):
        time.sleep(0.15)
        ctx.check_deadline()
        return {"y": req["x"]}


@pytest.fixture
def channel():
    router = Router()
    router.add_service(SVC, Impl())
    server = Server(router)
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    yield ch
    ch.close()


def test_unary(channel):
    m = channel.typed(SVC)
    assert m.Double({"x": 21})["y"] == 42


def test_server_stream(channel):
    m = channel.typed(SVC)
    assert [r["y"] for r in m.CountTo({"x": 4})] == [0, 1, 2, 3]


def test_client_stream(channel):
    m = channel.typed(SVC)
    assert m.Sum([{"x": i} for i in range(10)])["y"] == 45


def test_duplex(channel):
    m = channel.typed(SVC)
    assert [r["y"] for r in m.Echo([{"x": 1}, {"x": 2}])] == [1, 2]


def test_error_propagation(channel):
    m = channel.typed(SVC)
    with pytest.raises(RpcError) as ei:
        m.Fail({"x": 0})
    assert ei.value.code == Status.NOT_FOUND


def test_unknown_method(channel):
    with pytest.raises(RpcError) as ei:
        channel.call(0xDEADBEEF, b"")
    assert ei.value.code == Status.UNIMPLEMENTED


def test_stream_cursor_resume(channel):
    """§7.5: drop mid-stream, reconnect with the cursor, no replay."""
    did = SVC.method("CountTo").id
    it = channel.call(did, wire.encode(Req, {"x": 6}), server_stream=True)
    got, cursor = [], 0
    for item in it:
        got.append(wire.decode(Res, item.payload)["y"])
        cursor = item.cursor
        if len(got) == 3:
            break
    it2 = channel.call(did, wire.encode(Req, {"x": 6}), server_stream=True,
                       cursor=cursor)
    rest = [wire.decode(Res, i.payload)["y"] for i in it2]
    assert got + rest == [0, 1, 2, 3, 4, 5]


def test_batch_dependency_chain(channel):
    did = SVC.method("Double").id
    res = channel.batch([
        {"method_id": did, "payload": wire.encode(Req, {"x": 3})},
        {"method_id": did, "input_from": 0},
        {"method_id": did, "input_from": 1},
    ])
    ys = [wire.decode(Res, r["payload"])["y"] for r in res]
    assert ys == [6, 12, 24]


def test_batch_failure_propagates_to_dependents(channel):
    fid = SVC.method("Fail").id
    did = SVC.method("Double").id
    res = channel.batch([
        {"method_id": fid, "payload": wire.encode(Req, {"x": 1})},
        {"method_id": did, "input_from": 0},
        {"method_id": did, "payload": wire.encode(Req, {"x": 1})},
    ])
    assert res[0]["status"] == Status.NOT_FOUND
    assert res[1]["status"] == Status.INVALID_ARGUMENT
    assert res[2]["status"] == Status.OK  # independent call unaffected


def test_batch_rejects_forward_reference(channel):
    did = SVC.method("Double").id
    res = channel.batch([
        {"method_id": did, "input_from": 1},
        {"method_id": did, "payload": wire.encode(Req, {"x": 1})},
    ])
    assert all(r["status"] == Status.INVALID_ARGUMENT for r in res)


def test_batch_server_stream_buffered(channel):
    cid = SVC.method("CountTo").id
    res = channel.batch([
        {"method_id": cid, "payload": wire.encode(Req, {"x": 3})}])
    assert res[0]["status"] == Status.OK
    ys = [wire.decode(Res, b)["y"] for b in res[0]["stream"]]
    assert ys == [0, 1, 2]


def test_deadline_expired_before_call(channel):
    m = channel.typed(SVC)
    with pytest.raises(RpcError) as ei:
        m.Double({"x": 1}, deadline=Deadline.after(-0.5))
    assert ei.value.code == Status.DEADLINE_EXCEEDED


def test_deadline_expires_mid_handler(channel):
    m = channel.typed(SVC)
    with pytest.raises(RpcError) as ei:
        m.Slow({"x": 1}, deadline=Deadline.after(0.05))
    assert ei.value.code == Status.DEADLINE_EXCEEDED


def test_deadline_http_header_roundtrip():
    d = Deadline.after(1.0)
    h = d.to_http_header()
    d2 = Deadline.from_http_header(h)
    assert abs(d.cutoff_ns() - d2.cutoff_ns()) < 10 ** 6  # ms precision


def test_future_dispatch_resolve(channel):
    sid = SVC.method("Slow").id
    h = channel.dispatch_future(sid, wire.encode(Req, {"x": 7}))
    results = list(channel.resolve_futures([h["id"]]))
    assert results[0]["status"] == Status.OK
    assert wire.decode(Res, results[0]["payload"])["y"] == 7


def test_future_idempotency_key(channel):
    sid = SVC.method("Slow").id
    key = uuid.uuid4()
    h1 = channel.dispatch_future(sid, wire.encode(Req, {"x": 1}),
                                 idempotency_key=key)
    h2 = channel.dispatch_future(sid, wire.encode(Req, {"x": 1}),
                                 idempotency_key=key)
    assert h1["id"] == h2["id"]
    assert h2["existing"] is True


def test_future_completed_resolves_immediately(channel):
    sid = SVC.method("Double").id
    h = channel.dispatch_future(sid, wire.encode(Req, {"x": 5}))
    time.sleep(0.2)  # let it complete
    t0 = time.monotonic()
    res = list(channel.resolve_futures([h["id"]]))
    assert time.monotonic() - t0 < 1.0
    assert res[0]["status"] == Status.OK


def test_future_discard_result(channel):
    sid = SVC.method("Double").id
    h = channel.dispatch_future(sid, wire.encode(Req, {"x": 5}),
                                discard_result=True)
    time.sleep(0.2)
    with pytest.raises(RpcError) as ei:
        channel.cancel_future(h["id"])  # result discarded -> NOT_FOUND
    assert ei.value.code == Status.NOT_FOUND


def test_future_ownership():
    """A caller that does not own a future gets PERMISSION_DENIED (§7.6.1)."""
    from repro.core.rpc.futures import FutureManager
    fm = FutureManager()
    fid, _ = fm.dispatch("alice", lambda: (time.sleep(0.1), b"")[1])
    with pytest.raises(RpcError) as ei:
        next(iter(fm.resolve("bob", [fid])))
    assert ei.value.code == Status.PERMISSION_DENIED
    with pytest.raises(RpcError):
        fm.cancel("bob", fid)


def test_future_retention_eviction():
    from repro.core.rpc.futures import InMemoryFutureStorage
    st = InMemoryFutureStorage(max_completed=2)
    ids = [uuid.uuid4() for _ in range(3)]
    for i, fid in enumerate(ids):
        st.persist("o", fid, {"id": fid, "status": 0})
    assert st.fetch(ids[0]) is None      # evicted by count
    assert st.fetch(ids[2]) is not None


def test_discovery(channel):
    d = channel.discover()
    names = {m["name"] for m in d["methods"]}
    assert {"Double", "CountTo", "Sum", "Echo"} <= names
    ids = {m["routing_id"] for m in d["methods"]}
    assert len(ids) == len(d["methods"])  # no collisions


def test_tcp_transport():
    router = Router()
    router.add_service(SVC, Impl())
    server = Server(router)
    host, port, lsock = server.listen_tcp()
    ch = Channel(TcpTransport.connect(host, port))
    try:
        m = ch.typed(SVC)
        assert m.Double({"x": 4})["y"] == 8
        assert [r["y"] for r in m.CountTo({"x": 3})] == [0, 1, 2]
    finally:
        ch.close()
        lsock.close()


def test_unary_framing_overhead_is_9_bytes_each_way():
    """§7.2: 18 bytes of framing overhead for a complete unary RPC."""
    from repro.core.rpc.framing import HEADER_SIZE, Frame, encode_frame
    f = encode_frame(Frame(1, b"payload"))
    assert len(f) - len(b"payload") == HEADER_SIZE == 9


def test_reserved_method_ids_cannot_be_registered():
    router = Router()
    with pytest.raises(T.SchemaError):
        router.register_handler(W.METHOD_BATCH, lambda r, c: r)


def test_http1_transport_unary():
    """§7.7: the same protocol over an HTTP/1.1 envelope, no proxies."""
    from repro.core.rpc.transport import Http1Transport, connected_pair

    router = Router()
    router.add_service(SVC, Impl())
    server = Server(router)
    c_raw, s_raw = connected_pair()
    http_server = Http1Transport(s_raw, client=False)
    http_client = Http1Transport(c_raw, client=True)
    server.serve_transport(http_server, blocking=False)
    ch = Channel(http_client)
    try:
        m = ch.typed(SVC)
        assert m.Double({"x": 30})["y"] == 60
        # server-stream frames arrive inside HTTP response bodies
        assert [r["y"] for r in m.CountTo({"x": 3})] == [0, 1, 2]
    finally:
        ch.close()


def test_fig2_wire_encoding_sizes():
    """Paper Fig. 2: uuid + 4 bfloat16 embedding = 28 bytes in Bebop vs 48
    in protobuf (uuid as 36-char ASCII string)."""
    from repro.core import varint as V
    Emb = T.Struct("Emb", [T.Field("id", T.UUID),
                           T.Field("v", T.Array(T.BFLOAT16))])
    val = {"id": uuid.UUID("550e8400-e29b-41d4-a716-446655440000"),
           "v": np.asarray([1.0, 2.0, 3.0, 4.0], np.float32)}
    b = wire.encode(Emb, val)
    assert len(b) == 28  # 16B uuid + 4B count + 8B bf16 data
    v = V.encode(Emb, val)
    assert len(v) == 48  # 2B tag + 36B ascii uuid + 2B tag + 8B data
