"""Dry-run machinery tests on the single host device (full meshes are
exercised by launch/dryrun.py with the 512-device flag; here we verify the
cell construction, sharding specs and the HLO analyzer)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.cells import arch_shape_cells, input_specs
from repro.launch.mesh import make_host_mesh
from repro.launch.roofline import model_flops_for, roofline_terms
from repro.launch.shardings import param_specs, zero_specs
from repro.utils import hlo as H


def test_cells_enumeration():
    cells = arch_shape_cells()
    assert len(cells) == 40  # 10 archs x 4 shapes
    skips = [c for c in cells if c[2]]
    assert len(skips) == 8   # long_500k for full-attention archs
    for arch, shape, why in skips:
        assert shape == "long_500k"
        assert get_config(arch).family not in ("ssm", "hybrid")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_no_allocation(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        specs = input_specs(cfg, shape)
        for v in jax.tree.leaves(specs):
            assert isinstance(v, jax.ShapeDtypeStruct)


def test_param_specs_shard_big_leaves():
    cfg = get_config("qwen2-72b")
    from repro.models import get_model
    model = get_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    specs = param_specs(cfg, shapes, mesh)
    flat_sh, _ = jax.tree_util.tree_flatten_with_path(shapes)
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    # embedding sharded on vocab
    assert "model" in tuple(specs["embed"])
    assert "model" in tuple(specs["lm_head"])
    # attention projections sharded
    assert "model" in tuple(specs["layers"]["attn"]["wq"])
    assert "model" in tuple(specs["layers"]["mlp"]["w_down"])
    # norms replicated
    assert tuple(specs["final_norm"]) == (None,)


def test_zero_specs_add_data_axis():
    cfg = get_config("qwen2-1.5b")
    from repro.models import get_model
    shapes = jax.eval_shape(get_model(cfg).init, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    pspecs = param_specs(cfg, shapes, mesh)
    zspecs = zero_specs(pspecs, shapes, mesh)
    # with dp size 1, nothing changes
    assert jax.tree.all(jax.tree.map(
        lambda a, b: tuple(a) == tuple(b), pspecs, zspecs,
        is_leaf=lambda x: isinstance(x, P)))


def test_model_flops_sane():
    f = model_flops_for("qwen2-72b", "train_4k")
    # 6 * 72.7e9 * (4096*256) tokens
    assert 4e17 < f < 5e17
    f2 = model_flops_for("qwen2-moe-a2.7b", "train_4k")
    # active params only
    assert f2 < model_flops_for("qwen2-72b", "train_4k") / 10


def test_roofline_terms_math():
    rec = {"flops_per_device": 197e12, "bytes_per_device": 819e9,
           "collective_bytes": {"total": 50e9},
           "score_bytes_per_device": 0.0}
    t = roofline_terms(rec, model_flops=197e12 * 256, chips=256)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9
    assert abs(t["useful_ratio"] - 1.0) < 1e-9


def test_hlo_analyzer_scan_trip_counts():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    a = H.analyze(c.as_text(), while_trip_count=1)  # parsed from HLO cond
    assert abs(a["flops"] - 6 * 2 * 64 * 128 * 128) < 1e5


def test_hlo_analyzer_nested_scans():
    def f(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        c, _ = jax.lax.scan(outer, x, ws)
        return c
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    a = H.analyze(c.as_text())
    assert abs(a["flops"] - 4 * 3 * 2 * 32 * 64 * 64) < 1e5


def test_hlo_analyzer_collectives():
    mesh = make_host_mesh()
    from jax.sharding import NamedSharding

    def f(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(None, None)))
    # single-device: no collectives expected — analyzer returns zeros
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    a = H.analyze(c.as_text())
    assert a["collective_bytes"]["total"] == 0.0


def test_reduced_smoke_cell_lowers_on_host_mesh():
    """End-to-end mini dry-run: reduced config on the 1x1 mesh."""
    from repro.configs import reduced_config
    from repro.models import get_model
    from repro.train.optimizer import OptimizerConfig, init_opt_state
    from repro.train.train_loop import make_train_step
    cfg = reduced_config(get_config("gemma-2b"))
    model = get_model(cfg)
    mesh = make_host_mesh()
    params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_abs = jax.eval_shape(
        lambda p: init_opt_state(p, OptimizerConfig()), params_abs)
    batch_abs = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    step = make_train_step(model, OptimizerConfig())
    with mesh:
        lowered = jax.jit(step).lower(params_abs, opt_abs, batch_abs)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert float(ca.get("flops", 0)) > 0
