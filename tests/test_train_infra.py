"""Training substrate: pipeline, optimizer, checkpointing, fault tolerance,
end-to-end loss decrease + restart."""
import os
import tempfile
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data import (BufferSource, DataConfig, Pipeline, device_batches,
                        synthetic_corpus, write_example_pages)
from repro.train import (OptimizerConfig, PreemptionHandler, StepWatchdog,
                         TrainConfig, Trainer)
from repro.train.optimizer import (adamw_update, compress_grads,
                                   init_opt_state, lr_schedule)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def _mkdata(seq=16, n=128, vocab=512, rpp=8):
    toks = synthetic_corpus(seq, n, vocab, seed=3)
    buf = write_example_pages(seq, toks, records_per_page=rpp)
    return toks, buf


def test_pipeline_batches_and_cursor():
    toks, buf = _mkdata()
    dc = DataConfig(seq_len=16, global_batch=4, records_per_page=8)
    src = BufferSource(buf)
    pipe = Pipeline(dc, [src], len(src))
    batches = []
    for batch, cur in pipe:
        batches.append((batch, cur))
        if len(batches) == 5:
            break
    pipe.stop()
    assert batches[0][0]["tokens"].shape == (4, 16)
    # batch 0 == first 4 records
    np.testing.assert_array_equal(batches[0][0]["tokens"],
                                  toks[:4, :-1].astype(np.int32))
    # restart from cursor of batch 2 reproduces batch 3 exactly
    pipe2 = Pipeline(dc, [src], len(src), cursor=batches[2][1])
    nxt = next(iter(pipe2))
    pipe2.stop()
    np.testing.assert_array_equal(nxt[0]["tokens"], batches[3][0]["tokens"])


def test_pipeline_host_sharding_disjoint():
    toks, buf = _mkdata(n=64)
    src = BufferSource(buf)
    seen = []
    for h in range(2):
        dc = DataConfig(seq_len=16, global_batch=8, num_hosts=2,
                        host_index=h, records_per_page=8)
        pipe = Pipeline(dc, [src], len(src))
        got = []
        for batch, cur in pipe:
            got.append(batch["tokens"])
        pipe.stop()
        seen.append(np.concatenate(got) if got else np.zeros((0, 16)))
    a = {r.tobytes() for r in seen[0]}
    b = {r.tobytes() for r in seen[1]}
    assert a and b and not (a & b)  # disjoint shards


def test_hedged_reads_fire_under_straggler():
    toks, buf = _mkdata()
    slow = BufferSource(buf, delay_s=0.8, delay_every=2)
    fast = BufferSource(buf)
    dc = DataConfig(seq_len=16, global_batch=4, records_per_page=8,
                    hedge_after_s=0.05)
    pipe = Pipeline(dc, [slow, fast], len(slow))
    n = 0
    for _ in pipe:
        n += 1
        if n >= 6:
            break
    frac = pipe.hedged_fraction
    pipe.stop()
    assert frac > 0


def test_device_batches_raw_payloads():
    toks, buf = _mkdata()
    dc = DataConfig(seq_len=16, global_batch=4, records_per_page=8)
    stride = 16 + 4 * 17
    got = list(device_batches(buf, dc))
    assert got[0][0].shape == (4, stride)
    # decode on device and compare with source tokens
    from repro.core.device import decode_page_device
    from repro.data import example_layout
    cols = decode_page_device(jnp.asarray(got[0][0]), example_layout(16))
    np.testing.assert_array_equal(np.asarray(cols["tokens"]),
                                  toks[:4].astype("<i4"))


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    fn = lr_schedule(cfg)
    assert float(fn(jnp.int32(0))) == 0.0
    assert abs(float(fn(jnp.int32(10))) - 1.0) < 1e-6
    assert float(fn(jnp.int32(100))) < 0.11


def test_grad_compression_bf16_and_int8():
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal(128), dtype=jnp.float32)}
    p = {"w": jnp.zeros(128)}
    cfg8 = OptimizerConfig(compression="int8")
    st = init_opt_state(p, cfg8)
    cg, st2 = compress_grads(g, st, cfg8)
    err = np.abs(np.asarray(cg["w"]) - np.asarray(g["w"]))
    assert err.max() < np.abs(np.asarray(g["w"])).max() / 100
    # error feedback carries the residual
    assert np.abs(np.asarray(st2["ef"]["w"])).max() > 0
    cfgb = OptimizerConfig(compression="bf16")
    cb, _ = compress_grads(g, init_opt_state(p, cfgb), cfgb)
    assert cb["w"].dtype == jnp.bfloat16


def test_int8_error_feedback_converges():
    """With error feedback the quantization bias cancels over steps."""
    cfg = OptimizerConfig(lr=0.05, warmup_steps=0, total_steps=400,
                          weight_decay=0.0, compression="int8")
    params = {"w": jnp.asarray([4.0, -2.0])}
    state = init_opt_state(params, cfg)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        grads, state = compress_grads(grads, state, cfg)
        params, state = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_atomic_roundtrip_and_retention():
    from repro.checkpoint import CheckpointManager
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 4), dtype=np.int32)}}
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=2)
        for step in (1, 2, 3):
            mgr.save(step, tree, data_cursor=step * 100, blocking=True)
        assert mgr.steps() == [2, 3]  # retention
        out, man = mgr.restore(3, tree)
        np.testing.assert_array_equal(out["a"], tree["a"])
        np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])
        assert man["data_cursor"] == 300
        assert man["complete"] is True


def test_checkpoint_crash_leaves_no_partial():
    from repro.checkpoint import CheckpointManager
    with tempfile.TemporaryDirectory() as td:
        CheckpointManager(td)
        # simulate a crash: tmp dir exists, no manifest rename happened
        os.makedirs(os.path.join(td, ".tmp_step_9"))
        mgr2 = CheckpointManager(td)  # next run GCs tmp
        assert mgr2.latest_step() is None
        assert not os.path.exists(os.path.join(td, ".tmp_step_9"))


def test_checkpoint_bf16_tensors():
    from repro.checkpoint import CheckpointManager
    tree = {"w": jnp.asarray(np.random.default_rng(1)
                             .standard_normal((4, 4)), dtype=jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(1, tree, blocking=True)
        out, _ = mgr.restore(1, tree)
        np.testing.assert_array_equal(
            np.asarray(tree["w"], dtype=np.float32),
            np.asarray(out["w"], dtype=np.float32))


def test_checkpoint_corruption_detected():
    from repro.checkpoint import CheckpointManager
    tree = {"a": np.arange(100, dtype=np.float32)}
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(1, tree, blocking=True)
        shard = os.path.join(td, "step_1", "shard_00000.bebop")
        data = bytearray(open(shard, "rb").read())
        data[-20] ^= 0xFF
        open(shard, "wb").write(bytes(data))
        with pytest.raises(Exception):
            mgr.restore(1, tree)


# --------------------------------------------------------------------------
# fault handling
# --------------------------------------------------------------------------

def test_preemption_flag():
    h = PreemptionHandler()
    assert not h.preempted
    h.trigger()
    assert h.preempted


def test_watchdog_detects_hang():
    events = []
    w = StepWatchdog(0.15, on_hang=lambda: events.append(1))
    w.step_started()
    time.sleep(0.5)
    w.stop()
    assert w.hung and events


def test_watchdog_ok_when_steps_finish():
    w = StepWatchdog(0.3)
    for _ in range(3):
        w.step_started()
        time.sleep(0.02)
        w.step_finished()
    time.sleep(0.4)
    w.stop()
    assert not w.hung


# --------------------------------------------------------------------------
# end-to-end training + restart
# --------------------------------------------------------------------------

def test_train_loss_decreases_and_restart_resumes():
    cfg = reduced_config(get_config("gemma-2b"))
    seq, gb = 16, 4
    toks = synthetic_corpus(seq, 256, cfg.vocab_size, seed=5)
    buf = write_example_pages(seq, toks, records_per_page=8)
    dc = DataConfig(seq_len=seq, global_batch=gb, records_per_page=8)
    src = BufferSource(buf)
    with tempfile.TemporaryDirectory() as td:
        pipe = Pipeline(dc, [src], len(src))
        tr = Trainer(cfg,
                     OptimizerConfig(lr=1e-3, warmup_steps=2,
                                     total_steps=40),
                     TrainConfig(steps=12, ckpt_every=6, ckpt_dir=td,
                                 log_every=4),
                     data=iter(pipe))
        res = tr.run()
        pipe.stop()
        assert res["status"] == "done" and res["step"] == 12
        assert res["losses"][-1][1] < res["losses"][0][1]
        # restart resumes step + cursor from the checkpoint
        pipe2 = Pipeline(dc, [src], len(src), cursor=tr.data_cursor)
        tr2 = Trainer(cfg,
                      OptimizerConfig(lr=1e-3, warmup_steps=2,
                                      total_steps=40),
                      TrainConfig(steps=14, ckpt_every=6, ckpt_dir=td,
                                  log_every=4),
                      data=iter(pipe2))
        assert tr2.step == 12
        res2 = tr2.run()
        pipe2.stop()
        assert res2["step"] == 14


def test_preemption_emergency_checkpoint():
    cfg = reduced_config(get_config("qwen2-1.5b"))
    seq, gb = 16, 4
    toks = synthetic_corpus(seq, 128, cfg.vocab_size, seed=6)
    buf = write_example_pages(seq, toks, records_per_page=8)
    dc = DataConfig(seq_len=seq, global_batch=gb, records_per_page=8)
    src = BufferSource(buf)
    with tempfile.TemporaryDirectory() as td:
        pipe = Pipeline(dc, [src], len(src))
        tr = Trainer(cfg, OptimizerConfig(),
                     TrainConfig(steps=50, ckpt_every=100, ckpt_dir=td),
                     data=iter(pipe))
        tr.preemption.trigger()  # simulate SIGTERM
        res = tr.run()
        pipe.stop()
        assert res["status"] == "preempted"
        assert tr.ckpt.latest_step() is not None  # emergency checkpoint


def test_checkpoint_elastic_restore_with_shardings():
    """Restore applies target shardings (elastic load onto a new mesh)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    shardings = {"w": NamedSharding(mesh, P(None, None))}
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(1, tree, mesh_shape=(16, 16),
                 mesh_axes=("data", "model"), blocking=True)
        out, man = mgr.restore(1, tree, shardings=shardings)
        assert tuple(int(x) for x in man["mesh_shape"]) == (16, 16)
        assert isinstance(out["w"], jax.Array)
        assert out["w"].sharding == shardings["w"]
        np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
