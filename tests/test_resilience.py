"""Fault tolerance of the RPC stack, piece by piece (no model, no JAX).

Covers: the shared retry policy (core/retry.py), frame validation against
desynced streams, the fault-injecting transport, fail-fast propagation
when a channel's read loop dies (the bug where pending calls blocked out
their full timeout), Http1Transport against adversarial byte streams,
server-side dedup (exactly-once), connection-close hooks, reconnecting
clients with idempotent retry and cursor-resumed streams, and graceful
drain.  tests/test_chaos.py runs the same machinery end-to-end over a
real engine.
"""
import queue
import threading
import time

import pytest

from repro.core.retry import RetryPolicy, retry
from repro.core.rpc import (Channel, ClientTimeout, ConnectionState,
                            DedupCache, FaultInjectingTransport, FaultSpec,
                            Flags, Frame, FrameReader, FramingError,
                            Http1Transport, ResilientChannel, Router,
                            RpcError, Server, Status, TransportError,
                            connected_pair, encode_frame)
from repro.core.rpc.transport import InMemoryTransport


# -- core/retry.py: the shared backoff policy ---------------------------------

def test_retry_succeeds_after_transient_failures():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise ConnectionError("transient")
        return "ok"

    assert retry(flaky, attempts=4, base_delay=0.1,
                 sleep=sleeps.append) == "ok"
    assert calls["n"] == 3
    assert sleeps == [0.1, 0.2]  # exponential, no jitter by default


def test_retry_exhausts_and_reraises():
    sleeps = []
    with pytest.raises(ConnectionError):
        retry(lambda: (_ for _ in ()).throw(ConnectionError("down")),
              attempts=3, base_delay=0.01, sleep=sleeps.append)
    assert len(sleeps) == 2  # no sleep after the final attempt


def test_retry_non_retryable_raises_immediately():
    calls = {"n": 0}

    def boom():
        calls["n"] += 1
        raise ValueError("logic bug")

    with pytest.raises(ValueError):
        retry(boom, attempts=5, sleep=lambda s: None)
    assert calls["n"] == 1


def test_retry_policy_delay_cap_and_jitter_bounds():
    p = RetryPolicy(base_delay=0.1, multiplier=2.0, max_delay=0.5,
                    jitter=0.25)
    import random
    rng = random.Random(7)
    for k in range(1, 10):
        d = p.delay(k, rng)
        cap = min(0.1 * 2 ** (k - 1), 0.5)
        assert 0.75 * cap - 1e-9 <= d <= 1.25 * cap + 1e-9
    # no jitter -> exact cap
    assert RetryPolicy(base_delay=0.1, max_delay=0.5).delay(9) == 0.5


def test_train_fault_reexports_shared_retry():
    from repro.train import fault
    assert fault.retry is retry
    assert fault.RetryPolicy is RetryPolicy


# -- framing validation: desynced streams die loudly ---------------------------

def test_frame_reader_rejects_impossible_length():
    r = FrameReader()
    bad = bytearray(encode_frame(Frame(1, b"hello")))
    bad[3] |= 0x80  # what the chaos transport's corrupt fault does
    with pytest.raises(FramingError):
        r.feed(bytes(bad))


def test_frame_reader_rejects_unknown_flags():
    r = FrameReader()
    with pytest.raises(FramingError):
        r.feed(b"\x00\x00\x00\x00\x40\x01\x00\x00\x00")  # flags 0x40


def test_frame_reader_accepts_all_known_flags():
    r = FrameReader()
    f = Frame(3, b"x", Flags.END_STREAM | Flags.ERROR, cursor=9)
    out = r.feed(encode_frame(f))
    assert out == [f]


# -- FaultInjectingTransport: deterministic chaos ------------------------------

def test_fault_transport_scripted_drop():
    ct, st = connected_pair()
    chaos = FaultInjectingTransport(ct, script={0: "drop"})
    chaos.send(b"gone")
    chaos.send(b"kept")
    assert st.recv(timeout=1.0) == b"kept"
    assert chaos.injected["drop"] == 1


def test_fault_transport_corrupt_is_always_detectable():
    ct, st = connected_pair()
    chaos = FaultInjectingTransport(ct, script={0: "corrupt"})
    frame = encode_frame(Frame(1, b"payload"))
    with pytest.raises(ConnectionError):
        chaos.send(frame)
    r = FrameReader()
    with pytest.raises(FramingError):
        while True:
            data = st.recv(timeout=1.0)
            if not data:
                break  # damaged bytes + close: a stall is also a pass
            r.feed(data)
    assert chaos.injected["corrupt"] == 1


def test_fault_transport_truncate_poisons_connection():
    ct, st = connected_pair()
    chaos = FaultInjectingTransport(ct, seed=5, script={0: "truncate"})
    frame = encode_frame(Frame(1, b"a longer payload here"))
    with pytest.raises(ConnectionError):
        chaos.send(frame)
    got = b""
    while True:
        data = st.recv(timeout=1.0)
        if not data:
            break
        got += data
    assert len(got) < len(frame)  # strict prefix, then close
    with pytest.raises(ConnectionError):
        chaos.send(b"after")  # the wrapper stays broken


def test_fault_transport_seeded_rates_are_deterministic():
    spec = FaultSpec(drop=0.3, delay=0.2, delay_s=0.0)

    def run(seed):
        ct, st = connected_pair()
        chaos = FaultInjectingTransport(ct, spec, seed=seed)
        for i in range(50):
            try:
                chaos.send(b"m%d" % i)
            except ConnectionError:
                break
        return dict(chaos.injected)

    assert run(11) == run(11)
    assert run(11) != run(12)  # different seed, different schedule
    assert sum(run(11).values()) > 0


def test_fault_spec_rejects_rates_over_one():
    with pytest.raises(ValueError):
        FaultSpec(drop=0.7, corrupt=0.5)


# -- the read-loop regression: pending calls fail fast, not at timeout --------

def test_pending_call_fails_fast_when_stream_desyncs():
    """Pre-fix, a read loop killed by FramingError left the pending call
    blocked for its full client timeout (30s here)."""
    ct, st = connected_pair()
    ch = Channel(ct)
    errs: "queue.Queue" = queue.Queue()

    def call():
        t0 = time.monotonic()
        try:
            ch.call(0x99, b"req", timeout=30.0)
            errs.put(("no error", 0.0))
        except RpcError as e:
            errs.put((e, time.monotonic() - t0))

    th = threading.Thread(target=call, daemon=True)
    th.start()
    time.sleep(0.1)            # let the request frame go out
    st.send(b"\xff" * 32)      # garbage: client FrameReader desyncs
    e, elapsed = errs.get(timeout=5.0)
    assert isinstance(e, TransportError)
    assert elapsed < 5.0       # NOT the 30s timeout
    ch.close()


def test_call_on_dead_channel_fails_immediately():
    ct, st = connected_pair()
    ch = Channel(ct)
    st.close()                 # peer goes away
    deadline = time.monotonic() + 5.0
    while ch.alive and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not ch.alive
    t0 = time.monotonic()
    with pytest.raises(TransportError):
        ch.call(0x99, b"req", timeout=30.0)
    assert time.monotonic() - t0 < 1.0
    ch.close()


def test_client_timeout_is_typed():
    ct, st = connected_pair()
    ch = Channel(ct)            # nobody serves the other side
    with pytest.raises(ClientTimeout) as ei:
        ch.call(0x99, b"req", timeout=0.05)
    assert ei.value.code == Status.DEADLINE_EXCEEDED  # wire-compatible
    ch.close()


# -- Http1Transport: adversarial byte streams ----------------------------------

class _ChunkedInner(InMemoryTransport):
    """Inner transport that delivers its buffer in tiny chunks."""

    def __init__(self, chunks):
        self._chunks = list(chunks)
        self._closed = False

    def recv(self, timeout=None):
        if not self._chunks:
            return b""
        return self._chunks.pop(0)

    def send(self, data):
        raise AssertionError("recv-only fixture")

    def close(self):
        self._closed = True


def _http_body(body: bytes) -> bytes:
    return (b"POST /bebop HTTP/1.1\r\ncontent-length: "
            + str(len(body)).encode() + b"\r\n\r\n" + body)


def test_http1_partial_reads_across_header_and_body():
    raw = _http_body(b"hello-bebop")
    # 1-byte chunks: every header/body boundary is hit mid-token
    t = Http1Transport(_ChunkedInner([raw[i:i + 1]
                                      for i in range(len(raw))]),
                       client=False)
    assert t.recv(timeout=1.0) == b"hello-bebop"


def test_http1_two_envelopes_split_at_odd_boundary():
    raw = _http_body(b"first") + _http_body(b"second-longer")
    cut = len(_http_body(b"first")) + 7  # mid-header of the second
    t = Http1Transport(_ChunkedInner([raw[:cut], raw[cut:]]), client=False)
    assert t.recv(timeout=1.0) == b"first"
    assert t.recv(timeout=1.0) == b"second-longer"


def test_http1_oversized_content_length_rejected():
    head = b"POST / HTTP/1.1\r\ncontent-length: 999999999999\r\n\r\n"
    t = Http1Transport(_ChunkedInner([head]), client=False)
    with pytest.raises(FramingError):
        t.recv(timeout=1.0)


def test_http1_unparseable_content_length_rejected():
    for v in (b"-5", b"1e9", b"two"):
        head = b"POST / HTTP/1.1\r\ncontent-length: " + v + b"\r\n\r\n"
        t = Http1Transport(_ChunkedInner([head]), client=False)
        with pytest.raises(FramingError):
            t.recv(timeout=1.0)


def test_http1_header_flood_rejected():
    t = Http1Transport(_ChunkedInner([b"X" * 70000]), client=False)
    with pytest.raises(FramingError):
        t.recv(timeout=1.0)


def test_http1_mid_body_disconnect_returns_closed():
    raw = _http_body(b"full-body-here")
    t = Http1Transport(_ChunkedInner([raw[:len(raw) - 4]]), client=False)
    assert t.recv(timeout=1.0) == b""  # clean "closed", not a hang/crash


def test_http1_send_error_maps_status_to_http():
    ct, st = connected_pair()
    server = Http1Transport(st, client=False)
    server.send_error(Status.UNAVAILABLE, b"draining")
    _, raw = ct._rx.get(timeout=1.0)
    head = raw.split(b"\r\n\r\n", 1)[0]
    assert head.startswith(b"HTTP/1.1 503")
    assert b"bebop-status: 14" in head
    client = Http1Transport(ct, client=True)
    ct._rx.put((time.monotonic(), raw))
    assert client.recv(timeout=1.0) == b"draining"


# -- DedupCache: exactly-once bookkeeping --------------------------------------

def test_dedup_first_owns_then_replays():
    d = DedupCache()
    state, e = d.begin("c1\x00k1")
    assert state == "mine"
    d.finish(e, b"result", Flags.END_STREAM, None)
    state2, e2 = d.begin("c1\x00k1")
    assert state2 == "done" and e2 is e and e2.payload == b"result"
    assert d.hits == 1


def test_dedup_concurrent_retry_waits_for_owner():
    d = DedupCache()
    _, e = d.begin("k")
    state, e2 = d.begin("k")
    assert state == "wait" and e2 is e
    threading.Timer(0.05, lambda: d.finish(e, b"late", 1, None)).start()
    assert e2.ready.wait(timeout=2.0)
    assert e2.payload == b"late"


def test_dedup_first_final_frame_wins():
    d = DedupCache()
    _, e = d.begin("k")
    d.finish(e, b"first", Flags.END_STREAM, None)
    d.finish(e, b"second", Flags.END_STREAM, None)
    assert e.payload == b"first"


def test_dedup_is_bounded():
    d = DedupCache(max_entries=8)
    for i in range(50):
        _, e = d.begin(f"k{i}")
        d.finish(e, b"x", 1, None)
    assert len(d) <= 8


def test_dedup_keys_are_client_scoped():
    from repro.core.rpc import CLIENT_ID_KEY, IDEMPOTENCY_KEY, RpcContext
    a = RpcContext(metadata={CLIENT_ID_KEY: "a", IDEMPOTENCY_KEY: "k"})
    b = RpcContext(metadata={CLIENT_ID_KEY: "b", IDEMPOTENCY_KEY: "k"})
    assert Server._dedup_key(a) != Server._dedup_key(b)
    assert Server._dedup_key(RpcContext(metadata={})) is None


# -- ConnectionState: close hooks ----------------------------------------------

def test_connection_state_hooks_fire_once():
    c = ConnectionState("p")
    fired = []
    c.on_close(lambda: fired.append(1))
    c.close()
    c.close()
    assert fired == [1]


def test_connection_state_late_registration_fires_immediately():
    c = ConnectionState("p")
    c.close()
    fired = []
    c.on_close(lambda: fired.append(1))
    assert fired == [1]


def test_connection_state_discard_prevents_firing():
    c = ConnectionState("p")
    fired = []
    h = c.on_close(lambda: fired.append(1))
    c.discard(h)
    c.close()
    assert fired == []


def test_connection_state_hook_error_does_not_cascade():
    c = ConnectionState("p")
    fired = []
    c.on_close(lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    c.on_close(lambda: fired.append(1))
    c.close()
    assert fired == [1]


# -- ResilientChannel against a live server ------------------------------------

ECHO, COUNTED, TICKER, FAILER, SLOW = 0x100, 0x101, 0x102, 0x103, 0x104


class _TestService:
    def __init__(self):
        self.executions = 0
        self.lock = threading.Lock()

    def build(self) -> Server:
        r = Router()
        r.register_handler(ECHO, lambda req, ctx: bytes(req))

        def counted(req, ctx):
            with self.lock:
                self.executions += 1
            return b"run-" + bytes(req)
        r.register_handler(COUNTED, counted)

        def ticker(req, ctx):
            n = int(bytes(req) or b"5")
            for i in range(int(ctx.cursor), n):
                time.sleep(0.02)  # pace: frames aren't all pre-buffered
                ctx.set_cursor(i + 1)
                yield b"tick-%d" % i
        r.register_handler(TICKER, ticker, kind="server_stream")

        r.register_handler(FAILER, lambda req, ctx: (_ for _ in ()).throw(
            RpcError(Status.INVALID_ARGUMENT, "bad request")))

        def slow(req, ctx):
            time.sleep(0.3)
            return b"slow-done"
        r.register_handler(SLOW, slow)
        return Server(r)


def _factory(server, faults=None):
    """Transport factory: each dial is a fresh pair served by ``server``."""
    state = {"client": None, "server": None, "dials": 0}

    def dial():
        ct, st = connected_pair()
        if faults:
            spec, base_seed = faults
            ct = FaultInjectingTransport(ct, spec,
                                         seed=base_seed + 2 * state["dials"])
            st = FaultInjectingTransport(st, spec,
                                         seed=base_seed + 2 * state["dials"]
                                         + 1)
        server.serve_transport(st, blocking=False)
        state["client"], state["server"] = ct, st
        state["dials"] += 1
        return ct

    return dial, state


FAST = RetryPolicy(attempts=5, base_delay=0.01, max_delay=0.05, jitter=0.0,
                   retry_on=ResilientChannel.RETRYABLE)


def test_resilient_unary_reconnects_after_connection_loss():
    svc = _TestService()
    server = svc.build()
    dial, state = _factory(server)
    rc = ResilientChannel(dial, policy=FAST)
    assert rc.call(ECHO, b"one", timeout=2.0) == b"one"
    state["client"].close()  # kill the wire under the channel
    assert rc.call(ECHO, b"two", timeout=2.0) == b"two"
    assert rc.reconnects == 1
    rc.close()


def test_resilient_unary_exactly_once_when_response_lost():
    """The response frame is dropped; the retry must replay the cached
    response, not run the handler twice."""
    svc = _TestService()
    server = svc.build()
    dial, state = _factory(server)
    rc = ResilientChannel(dial, policy=FAST)
    # wrap the server side AFTER dialing: drop its first send (the response)
    ct, st = connected_pair()
    chaos = FaultInjectingTransport(st, script={0: "drop"})
    server.serve_transport(chaos, blocking=False)
    rc._channel = Channel(ct, metadata=rc.metadata)
    out = rc.call(COUNTED, b"x", timeout=0.4)
    assert out == b"run-x"
    assert svc.executions == 1  # exactly once, despite the client retry
    assert server.dedup.hits >= 1
    rc.close()


def test_resilient_server_errors_are_not_retried():
    svc = _TestService()
    server = svc.build()
    dial, _ = _factory(server)
    rc = ResilientChannel(dial, policy=FAST)
    with pytest.raises(RpcError) as ei:
        rc.call(FAILER, b"", timeout=2.0)
    assert ei.value.code == Status.INVALID_ARGUMENT
    assert rc.retries == 0  # the server answered; answering "no" is final
    rc.close()


def test_resilient_stream_resumes_from_cursor():
    svc = _TestService()
    server = svc.build()
    dial, state = _factory(server)
    rc = ResilientChannel(dial, policy=FAST)
    got = []
    it = rc.call(TICKER, b"8", server_stream=True, timeout=2.0)
    for item in it:
        got.append(bytes(item.payload))
        if len(got) == 3:
            state["server"].close()  # server-side wire dies mid-stream
    assert got == [b"tick-%d" % i for i in range(8)]  # gap- and dup-free
    assert rc.reconnects >= 1
    rc.close()


def test_resilient_stream_survives_repeated_faults():
    svc = _TestService()
    server = svc.build()
    spec = FaultSpec(disconnect=0.12)
    dial, _ = _factory(server, faults=(spec, 40))
    rc = ResilientChannel(dial, policy=RetryPolicy(
        attempts=10, base_delay=0.01, max_delay=0.05,
        retry_on=ResilientChannel.RETRYABLE))
    it = rc.call(TICKER, b"12", server_stream=True, timeout=2.0)
    got = [bytes(i.payload) for i in it]
    assert got == [b"tick-%d" % i for i in range(12)]
    rc.close()


def test_resilient_gives_up_after_policy_attempts():
    def dead():
        raise ConnectionError("refused")

    sleeps = []
    rc = ResilientChannel(dead, policy=RetryPolicy(
        attempts=3, base_delay=0.01, max_delay=0.02,
        retry_on=ResilientChannel.RETRYABLE), sleep=sleeps.append)
    with pytest.raises(TransportError):
        rc.call(ECHO, b"x", timeout=0.2)
    assert sleeps  # it did back off between attempts
    rc.close()


def test_resilient_typed_client_works():
    # TypedClient only needs .call, so it runs unchanged over the
    # resilient wrapper — exercised end-to-end in test_chaos.py; here we
    # just check the plumbing accepts it.
    svc = _TestService()
    server = svc.build()
    dial, _ = _factory(server)
    rc = ResilientChannel(dial, policy=FAST)
    assert rc.discover()["methods"]
    rc.close()


# -- graceful drain ------------------------------------------------------------

def test_drain_finishes_inflight_then_refuses():
    svc = _TestService()
    server = svc.build()
    server.drain_exempt.add(ECHO)  # stands in for the Health probe
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    results: "queue.Queue" = queue.Queue()
    th = threading.Thread(
        target=lambda: results.put(ch.call(SLOW, b"", timeout=5.0)),
        daemon=True)
    th.start()
    time.sleep(0.1)  # the slow call is now in flight
    t0 = time.monotonic()
    drained: "queue.Queue" = queue.Queue()
    threading.Thread(target=lambda: drained.put(server.drain(timeout=5.0)),
                     daemon=True).start()
    time.sleep(0.05)
    assert server.draining
    # exempt method still answers while draining
    ct2, st2 = connected_pair()
    server.serve_transport(st2, blocking=False)
    ch2 = Channel(ct2)
    assert ch2.call(ECHO, b"probe", timeout=2.0) == b"probe"
    # non-exempt method is refused while draining
    with pytest.raises(RpcError) as ei:
        ch2.call(COUNTED, b"x", timeout=2.0)
    assert ei.value.code == Status.UNAVAILABLE
    # the in-flight slow call completed, and drain waited for it
    assert results.get(timeout=5.0) == b"slow-done"
    assert drained.get(timeout=5.0) is True
    assert time.monotonic() - t0 >= 0.1
    ch.close()
    ch2.close()


def test_connection_error_isolation():
    """A connection that turns to garbage kills itself, not the server."""
    svc = _TestService()
    server = svc.build()
    ct_bad, st_bad = connected_pair()
    server.serve_transport(st_bad, blocking=False)
    ct_bad.send(b"\xff" * 64)  # desync: server's FrameReader raises
    deadline = time.monotonic() + 5.0
    while server.conn_errors == 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert server.conn_errors == 1
    # a healthy connection is unaffected
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    assert ch.call(ECHO, b"still-alive", timeout=2.0) == b"still-alive"
    ch.close()
