"""The stochastic sampling tier: seeded draws, forks, rejection sampling.

Four contracts, each pinned here because a regression would be silent:

* **Determinism.**  A sampled request's tokens are a pure function of
  (seed, output index, candidate) — never of batch composition, the
  dense/paged split, or n (candidate 0 of a fork equals a solo run).
* **Greedy bit-identity.**  temperature 0 routes through the engine's
  original argmax lines, so the pre-sampling outputs are reproduced
  exactly, on every path.
* **Fork economics.**  n>1 candidates share the prompt's KV blocks
  through the refcounted allocator and diverge by copy-on-write; each
  candidate stops independently.
* **Distribution-correct speculation.**  Rejection-sampled verification
  emits the target distribution's marginal at every position (chi-squared
  checked), collapsing to exact-match at temperature 0.
"""
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.rpc import Channel, RpcError, Status, connected_pair
from repro.serving import (ContinuousBatcher, Engine, GenerationParams,
                           PagedBatcher, SamplingParams, ServeConfig,
                           build_server)
from repro.serving.sampling import (rejection_sample, sample_tokens,
                                    spec_uniforms, target_probs)
from repro.serving.service import InferenceService

SP = SamplingParams(temperature=0.8, top_p=0.9, seed=42)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen2-1.5b"))
    engine = Engine(cfg, ServeConfig(cache_len=96, max_new_tokens=8,
                                     max_batch=8, prefill_chunk=16,
                                     spec_decode=False, prefix_cache=False))
    yield cfg, engine


def _prompt(cfg, b=1, t=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (b, t)).astype(np.int32)


# -- SamplingParams / the sampler itself --------------------------------------

def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_target_probs_top_k_oracle():
    # logits [0,1,2,3] at temperature 1, top_k=2: mass on tokens {2,3}
    logits = np.array([[0.0, 1.0, 2.0, 3.0]])
    p = target_probs(logits, SamplingParams(temperature=1.0, top_k=2))[0]
    assert p[0] == 0.0 and p[1] == 0.0
    expect = np.exp([2.0, 3.0]) / np.exp([2.0, 3.0]).sum()
    np.testing.assert_allclose(p[2:], expect, rtol=1e-12)


def test_target_probs_top_p_oracle():
    # softmax = [0.5, 0.3, 0.15, 0.05]; top_p=0.7 keeps the tokens whose
    # EXCLUSIVE prefix mass is < 0.7: {0 (0.0), 1 (0.5)}, drops 2 (0.8)
    base = np.log(np.array([0.5, 0.3, 0.15, 0.05]))
    p = target_probs(base[None], SamplingParams(temperature=1.0, top_p=0.7))[0]
    assert p[2] == 0.0 and p[3] == 0.0
    np.testing.assert_allclose(p[:2], [0.5 / 0.8, 0.3 / 0.8], rtol=1e-12)


def test_target_probs_top_p_one_keeps_everything():
    logits = np.random.default_rng(0).normal(size=(3, 16))
    p = target_probs(logits, SamplingParams(temperature=0.7))
    assert (p > 0).all()
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-9)


def test_sample_tokens_greedy_is_argmax():
    logits = np.random.default_rng(1).normal(size=(4, 32)).astype(np.float32)
    got = sample_tokens(logits, SamplingParams(), index=5)
    np.testing.assert_array_equal(got, logits.argmax(-1))


def test_sample_tokens_pure_in_seed_index_candidate():
    logits = np.random.default_rng(2).normal(size=(1, 256)).astype(np.float32)
    a = [int(sample_tokens(logits, SP, index=i)[0]) for i in range(20)]
    b = [int(sample_tokens(logits, SP, index=i)[0]) for i in range(20)]
    assert a == b                       # same schedule, same tokens
    assert len(set(a)) > 1              # ...but the draws do vary by index
    other = [int(sample_tokens(logits, SamplingParams(
        temperature=0.8, top_p=0.9, seed=43), index=i)[0])
        for i in range(20)]
    assert a != other                   # and by seed


def test_sample_tokens_respects_top_k_support():
    logits = np.random.default_rng(3).normal(size=(1, 128)).astype(np.float32)
    sp = SamplingParams(temperature=1.5, top_k=4, seed=9)
    top4 = set(np.argsort(-logits[0])[:4].tolist())
    for i in range(40):
        assert int(sample_tokens(logits, sp, index=i)[0]) in top4


def test_uniform_schedule_candidate_prefix_invariance():
    # row r's uniforms are independent of how many candidates were asked
    # for — the property that makes fork candidate 0 equal a solo run
    u1 = spec_uniforms(SP, base_index=0, rows=1, width=8)
    u4 = spec_uniforms(SP, base_index=0, rows=4, width=8)
    np.testing.assert_array_equal(u4[:1], u1)
    # pure across calls and across the window boundary at index 64
    uw = spec_uniforms(SP, base_index=60, rows=2, width=8)
    np.testing.assert_array_equal(
        uw, spec_uniforms(SP, base_index=60, rows=2, width=8))
    assert ((0 <= uw) & (uw < 1)).all()


# -- rejection sampling -------------------------------------------------------

def _chi2(counts, probs):
    n = counts.sum()
    expect = probs * n
    mask = expect > 0
    return float(((counts[mask] - expect[mask]) ** 2 / expect[mask]).sum())


def test_rejection_sample_marginal_distribution():
    """The emitted token at a drafted position is ~ target p (SpecInfer).

    Chi-squared over 8 outcomes at 20k trials; the 0.001 critical value
    for df=7 is 24.32.  The uniforms come from a fixed-seed rng, so the
    test is deterministic.
    """
    rng = np.random.default_rng(11)
    v = 8
    p0 = rng.dirichlet(np.ones(v))
    p1 = rng.dirichlet(np.ones(v))
    probs = np.stack([p0, p1])
    draft = np.array([int(p0.argmax())])   # what an n-gram drafter would bet
    counts = np.zeros(v, np.int64)
    trials = 20_000
    for _ in range(trials):
        u = rng.random((2, 2))
        n_acc, tok, _ = rejection_sample(probs, draft, u[:, 0], u[:, 1])
        counts[int(draft[0]) if n_acc >= 1 else tok] += 1
    assert _chi2(counts, p0) < 24.32, f"marginal != target: {counts}"


def test_rejection_sample_accept_rate_matches_p_draft():
    rng = np.random.default_rng(13)
    v = 8
    p0 = rng.dirichlet(np.ones(v))
    draft = np.array([3])
    acc = sum(rejection_sample(np.stack([p0, p0]), draft,
                               rng.random(2), rng.random(2))[0] >= 1
              for _ in range(20_000))
    assert abs(acc / 20_000 - p0[3]) < 0.02


def test_rejection_sample_greedy_point_mass():
    # temperature 0's filtered target is a point mass: accept iff the
    # draft IS the argmax, resample to the argmax otherwise — the exact
    # match loop the greedy engine keeps
    p = np.zeros(8)
    p[5] = 1.0
    probs = np.stack([p, p])
    n_acc, tok, res = rejection_sample(probs, np.array([5]),
                                       np.array([0.99, 0.5]),
                                       np.array([0.5, 0.5]))
    assert n_acc == 1 and tok == 5
    n_acc, tok, res = rejection_sample(probs, np.array([2]),
                                       np.array([0.0, 0.5]),
                                       np.array([0.5, 0.5]))
    assert n_acc == 0 and tok == 5 and res


def test_rejection_sample_never_emits_filtered_token():
    # zero-probability draft tokens are always rejected, and the residual
    # can only land inside the target's support
    rng = np.random.default_rng(17)
    p = np.array([0.6, 0.4, 0.0, 0.0])
    probs = np.stack([p, p])
    for _ in range(200):
        n_acc, tok, _ = rejection_sample(probs, np.array([2]),
                                         rng.random(2), rng.random(2))
        assert n_acc == 0 and tok in (0, 1)


# -- engine determinism -------------------------------------------------------

def test_temperature_zero_bit_identical_to_greedy(setup):
    cfg, engine = setup
    p = _prompt(cfg, t=10, seed=20)
    legacy = engine.generate(p, max_new_tokens=6)
    explicit = engine.generate(p, max_new_tokens=6,
                               sampling=SamplingParams())
    np.testing.assert_array_equal(legacy, explicit)
    b = PagedBatcher(engine, max_batch=4)
    paged = b.generate(p, max_new_tokens=6, sampling=SamplingParams())
    b.close()
    np.testing.assert_array_equal(legacy, paged)


def test_sampled_paged_equals_dense_and_batch_independent(setup):
    cfg, engine = setup
    p = _prompt(cfg, t=12, seed=21)
    dense = engine.generate(p, max_new_tokens=6, sampling=SP)
    assert not np.array_equal(
        dense, engine.generate(p, max_new_tokens=6)), \
        "sampling degenerated to greedy"
    b = PagedBatcher(engine, max_batch=8)
    alone = b.generate(p, max_new_tokens=6, sampling=SP)
    np.testing.assert_array_equal(alone, dense)
    # same request inside a full batch of unrelated traffic
    others = [b.submit(_prompt(cfg, t=t, seed=t), max_new_tokens=6)
              for t in (5, 9, 17)]
    mixed = b.generate(p, max_new_tokens=6, sampling=SP)
    for f in others:
        f.result(timeout=300)
    b.close()
    np.testing.assert_array_equal(mixed, dense)


def test_sampled_run_reproducible_across_batchers(setup):
    cfg, engine = setup
    p = _prompt(cfg, t=9, seed=22)
    outs = []
    for _ in range(2):
        b = PagedBatcher(engine, max_batch=4)
        outs.append(b.generate(p, max_new_tokens=8, sampling=SP))
        b.close()
    np.testing.assert_array_equal(outs[0], outs[1])


# -- n>1 parallel sampling ----------------------------------------------------

def test_fork_candidate_zero_matches_solo_run(setup):
    cfg, engine = setup
    p = _prompt(cfg, t=12, seed=23)
    b = PagedBatcher(engine, max_batch=8)
    solo = b.generate(p, max_new_tokens=6, sampling=SP)
    forked = b.generate(p, max_new_tokens=6, sampling=SP, n=3)
    b.close()
    assert forked.shape == (3, 6)
    np.testing.assert_array_equal(forked[:1], solo)
    assert not np.array_equal(forked[1], forked[0]), "candidates identical"
    assert not np.array_equal(forked[2], forked[1]), "candidates identical"


def test_fork_greedy_candidates_all_identical(setup):
    cfg, engine = setup
    p = _prompt(cfg, t=10, seed=24)
    ref = engine.generate(p, max_new_tokens=5)
    b = PagedBatcher(engine, max_batch=4)
    forked = b.generate(p, max_new_tokens=5, n=4)
    b.close()
    for r in range(4):
        np.testing.assert_array_equal(forked[r:r + 1], ref)


def test_fork_shares_prompt_blocks(setup):
    """A block-aligned 32-token prompt forked 4 ways holds 2 shared
    blocks + 4 private tails at the first token — not 4 x 3 blocks."""
    cfg, engine = setup
    p = _prompt(cfg, t=32, seed=25)
    b = PagedBatcher(engine, max_batch=4)
    total = b.cache.layout.num_blocks
    free_before = b.cache.num_free_blocks
    used_at_first = []

    def hook(idx, tok):
        if idx == 0:
            used_at_first.append(total - b.cache.num_free_blocks)

    out = b.submit(p, max_new_tokens=8, sampling=SP, n=4,
                   on_token=hook).result(timeout=300)
    assert out.shape == (4, 8)
    assert used_at_first and used_at_first[0] <= 2 + 4 + 1, \
        f"fork did not share prompt blocks: {used_at_first[0]} used"
    assert b.cache.num_free_blocks == free_before, "blocks leaked"
    assert b.stats["forks"] == 3
    b.close()


def test_fork_unaligned_prompt_diverges_by_cow(setup):
    """With a partial boundary block the candidates' first divergent
    writes copy-on-write it instead of corrupting their siblings."""
    cfg, engine = setup
    p = _prompt(cfg, t=24, seed=26)   # 1.5 blocks at block_size 16
    b = PagedBatcher(engine, max_batch=4)
    solo = b.generate(p, max_new_tokens=8, sampling=SP)
    before = b.stats["cow_copies"]
    forked = b.generate(p, max_new_tokens=8, sampling=SP, n=3)
    assert b.stats["cow_copies"] > before, "boundary block never CoW'd"
    b.close()
    np.testing.assert_array_equal(forked[:1], solo)


def test_fork_per_candidate_stop(setup):
    """A candidate that samples the stop token freezes to stop padding
    while its siblings keep decoding to their own ends."""
    cfg, engine = setup
    p = _prompt(cfg, t=12, seed=27)
    b = PagedBatcher(engine, max_batch=4)
    free_ref = b.generate(p, max_new_tokens=8, sampling=SP, n=3)
    # pick a token only candidate 0 ever emits, mid-sequence, so the
    # rerun stops row 0 alone and the siblings must be untouched
    stop = None
    for j in range(2, 7):
        tok = int(free_ref[0, j])
        if tok not in free_ref[1] and tok not in free_ref[2] \
                and tok not in free_ref[0, :j]:
            stop, stop_j = tok, j
            break
    assert stop is not None, f"no unique candidate-0 token in {free_ref}"
    stopped = b.generate(p, max_new_tokens=8, sampling=SP, n=3,
                         stop_token=stop)
    b.close()
    # row 0: identical up to and including its stop token, padding after
    np.testing.assert_array_equal(stopped[0, :stop_j + 1],
                                  free_ref[0, :stop_j + 1])
    assert (stopped[0, stop_j:] == stop).all()
    # siblings: bit-identical to the stop-free run
    np.testing.assert_array_equal(stopped[1:], free_ref[1:])


def test_fork_on_dense_batcher_matches_paged(setup):
    cfg, engine = setup
    p = _prompt(cfg, t=10, seed=28)
    pb = PagedBatcher(engine, max_batch=4)
    paged = pb.generate(p, max_new_tokens=6, sampling=SP, n=3)
    pb.close()
    db = ContinuousBatcher(engine, max_batch=4, window_s=0.01)
    dense = db.generate(p, max_new_tokens=6, sampling=SP, n=3)
    db.close()
    np.testing.assert_array_equal(paged, dense)


def test_fork_multirow_prompt_rejected(setup):
    cfg, engine = setup
    b = PagedBatcher(engine, max_batch=4)
    with pytest.raises(ValueError):
        b.submit(_prompt(cfg, b=2, t=8, seed=29), max_new_tokens=4, n=2)
    b.close()


# -- speculative decoding at temperature > 0 ----------------------------------

def test_spec_sampled_deterministic_with_acceptance(setup):
    """Near-greedy sampled decode over a repetitive prompt: the drafter
    fires, rejection-sampling verification runs, and the whole pipeline
    stays seeded-deterministic across fresh batchers."""
    cfg, _ = setup
    engine = Engine(cfg, ServeConfig(cache_len=96, max_new_tokens=24,
                                     max_batch=4, prefill_chunk=16,
                                     spec_decode=True, spec_len=8,
                                     prefix_cache=False))
    motif = np.random.default_rng(31).integers(
        0, cfg.vocab_size, 6).astype(np.int32)
    p = np.tile(motif, 4)[None, :]
    sp = SamplingParams(temperature=0.05, seed=3)
    outs, spec_steps = [], []
    for _ in range(2):
        b = PagedBatcher(engine, max_batch=4)
        outs.append(b.generate(p, max_new_tokens=24, sampling=sp))
        spec_steps.append(b.stats["spec_steps"])
        b.close()
    np.testing.assert_array_equal(outs[0], outs[1])
    assert spec_steps[0] > 0, "drafter never fired on repetitive traffic"


def test_spec_greedy_still_bit_identical(setup):
    cfg, engine = setup
    spec_eng = Engine(cfg, ServeConfig(cache_len=96, max_new_tokens=24,
                                       max_batch=4, prefill_chunk=16,
                                       spec_decode=True, spec_len=8,
                                       prefix_cache=False))
    motif = np.random.default_rng(37).integers(
        0, cfg.vocab_size, 6).astype(np.int32)
    p = np.tile(motif, 4)[None, :]
    ref = engine.generate(p, max_new_tokens=24)
    b = PagedBatcher(spec_eng, max_batch=4)
    got = b.generate(p, max_new_tokens=24)
    assert b.stats["spec_accepted"] > 0
    b.close()
    np.testing.assert_array_equal(ref, got)


# -- GenerationParams ---------------------------------------------------------

def test_generation_params_absent_vs_explicit():
    gp = GenerationParams.from_request({}, default_max_new=16)
    assert gp.max_new_tokens == 16 and gp.temperature is None
    assert gp.stop_token is None and gp.n == 1
    gp = GenerationParams.from_request(
        {"max_new_tokens": 0, "temperature": 0.0, "seed": 0})
    assert gp.max_new_tokens == 0       # explicit 0 = prefill-only
    assert gp.temperature == 0.0        # explicit 0.0 = forced greedy
    assert gp.seed == 0                 # a real seed, not "absent"
    # the wire's negative stop sentinel decodes to "no stop token"
    assert GenerationParams.from_request({"stop_token": -1}).stop_token is None
    assert GenerationParams.from_request({"stop_token": 7}).stop_token == 7


def test_generation_params_validation_errors():
    for bad in ({"top_p": 0.0}, {"top_p": 1.5}, {"temperature": -1.0},
                {"top_k": -2}, {"n": 0}, {"max_new_tokens": -1}):
        with pytest.raises(RpcError) as ei:
            GenerationParams.from_request(bad)
        assert ei.value.code == Status.INVALID_ARGUMENT


def test_generation_params_resolve_against_config():
    sc = ServeConfig(temperature=0.6, top_k=5, top_p=0.8, seed=99)
    sp = GenerationParams.from_request({}).sampling(sc)
    assert sp == SamplingParams(temperature=0.6, top_k=5, top_p=0.8, seed=99)
    sp = GenerationParams.from_request(
        {"temperature": 0.0, "seed": 1}).sampling(sc)
    assert sp.greedy and sp.seed == 1 and sp.top_k == 5


def test_generation_params_through_paged_submit(setup):
    cfg, engine = setup
    b = PagedBatcher(engine, max_batch=4)
    gp = GenerationParams(temperature=0.8, top_p=0.9, seed=42,
                          max_new_tokens=6, n=2)
    out = b.submit(_prompt(cfg, t=12, seed=21), params=gp).result(timeout=300)
    direct = b.generate(_prompt(cfg, t=12, seed=21), max_new_tokens=6,
                        sampling=SP, n=2)
    b.close()
    np.testing.assert_array_equal(out, direct)


# -- the RPC service and router ----------------------------------------------

@pytest.fixture(scope="module")
def served(setup):
    cfg, engine = setup
    server = build_server(engine)
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    yield cfg, engine, ch.typed(InferenceService)
    ch.close()


def test_service_legacy_flat_request_unchanged(setup, served):
    cfg, engine, inf = served
    p = _prompt(cfg, t=8, seed=40)
    req = {"tokens": p.reshape(-1).astype(np.uint32), "batch": 1,
           "seq_len": 8, "max_new_tokens": 4}
    res = inf.Generate(dict(req))
    assert list(res["tokens"]) == list(inf.Generate(dict(req))["tokens"])
    ref = engine.generate(p, max_new_tokens=4)
    assert [int(x) for x in res["tokens"]] == ref.reshape(-1).tolist()


def test_service_sampled_generate_with_n(setup, served):
    cfg, engine, inf = served
    p = _prompt(cfg, t=8, seed=41)
    req = {"tokens": p.reshape(-1).astype(np.uint32), "batch": 1,
           "seq_len": 8, "max_new_tokens": 6, "temperature": 0.8,
           "top_p": 0.9, "seed": 42, "n": 3}
    res = inf.Generate(dict(req))
    assert res["batch"] == 3 and res["new_tokens"] == 6
    again = inf.Generate(dict(req))
    assert list(res["tokens"]) == list(again["tokens"])
    # candidate rows match the engine's own fork numbering
    ref = engine.generate(np.repeat(p, 3, axis=0), max_new_tokens=6,
                          sampling=SP)
    assert [int(x) for x in res["tokens"]] == ref.reshape(-1).tolist()


def test_service_explicit_zero_max_new_is_prefill_only(setup, served):
    cfg, engine, inf = served
    p = _prompt(cfg, t=8, seed=42)
    res = inf.Generate({"tokens": p.reshape(-1).astype(np.uint32),
                        "batch": 1, "seq_len": 8, "max_new_tokens": 0})
    assert res["new_tokens"] == 0 and len(res["tokens"]) == 0


def test_service_invalid_params_rejected(setup, served):
    cfg, engine, inf = served
    p = _prompt(cfg, t=8, seed=43)
    base = {"tokens": p.reshape(-1).astype(np.uint32), "batch": 1,
            "seq_len": 8, "max_new_tokens": 4}
    for extra in ({"top_p": 1.5}, {"n": 0}):
        with pytest.raises(RpcError) as ei:
            inf.Generate({**base, **extra})
        assert ei.value.code == Status.INVALID_ARGUMENT
    # n>1 needs a single-row prompt
    two = _prompt(cfg, b=2, t=8, seed=44)
    with pytest.raises(RpcError) as ei:
        inf.Generate({"tokens": two.reshape(-1).astype(np.uint32),
                      "batch": 2, "seq_len": 8, "max_new_tokens": 4,
                      "temperature": 0.8, "n": 2})
    assert ei.value.code == Status.INVALID_ARGUMENT


def test_router_passes_sampling_fields_byte_transparently(setup):
    """The router proxies raw bytes: a sampled n=3 Generate through the
    front door equals the same request against the engine directly."""
    cfg, engine = setup
    from repro.serving import InProcessReplica
    from repro.serving.router import RouterConfig, build_router_server

    reps = [InProcessReplica(engine, f"samp{i}") for i in range(2)]
    server, router = build_router_server(reps, RouterConfig(hedge=False))
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    inf = Channel(ct).typed(InferenceService)
    p = _prompt(cfg, t=8, seed=45)
    res = inf.Generate({"tokens": p.reshape(-1).astype(np.uint32),
                        "batch": 1, "seq_len": 8, "max_new_tokens": 6,
                        "temperature": 0.8, "top_p": 0.9, "seed": 42,
                        "n": 3})
    router.close()
    for r in reps:
        r.kill()
    assert res["batch"] == 3
    ref = engine.generate(np.repeat(p, 3, axis=0), max_new_tokens=6,
                          sampling=SP)
    assert [int(x) for x in res["tokens"]] == ref.reshape(-1).tolist()
