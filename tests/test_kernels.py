"""Per-kernel validation: shape/dtype sweeps, interpret=True vs ref oracle."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bebop_decode import decode_column, decode_columns
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import (paged_attention,
                                           paged_prefill_attention)
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan


# --------------------------------------------------------------------------
# bebop_decode
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,count,block_n", [
    (64, 16, 16), (256, 128, 64), (512, 1, 256), (128, 33, 128),
])
def test_decode_u32_column(rng, n, count, block_n):
    stride = 16 + 4 * count
    pages = rng.integers(0, 255, (n, stride), dtype=np.uint8)
    out = decode_column(jnp.asarray(pages), offset=16, count=count,
                        wire_dtype="uint32", block_n=block_n, interpret=True)
    expect = pages[:, 16:16 + 4 * count].copy().view("<u4")
    assert np.array_equal(np.asarray(out), expect)
    out_ref = ref.bytes_to_u32(jnp.asarray(pages), 16, count)
    assert np.array_equal(np.asarray(out_ref), expect)


@pytest.mark.parametrize("dim", [8, 64, 384])
def test_decode_bf16_column(rng, dim):
    n = 128
    stride = 2 * dim
    vals = rng.standard_normal((n, dim)).astype("<f4")
    raw = (vals.view("<u4") >> 16).astype("<u2")
    pages = raw.view("u1").reshape(n, stride)
    out = decode_column(jnp.asarray(pages), offset=0, count=dim,
                        wire_dtype="bfloat16", interpret=True)
    expect = (raw.astype("<u4") << 16).view("<f4")
    assert np.allclose(np.asarray(out), expect)


@pytest.mark.parametrize("wd,esize", [
    ("float32", 4), ("uint16", 2), ("int32", 4), ("uint8", 1),
    ("float16", 2),
])
def test_decode_column_dtypes(rng, wd, esize):
    n, count = 64, 24
    pages = rng.integers(0, 255, (n, 8 + esize * count), dtype=np.uint8)
    out = np.asarray(decode_column(jnp.asarray(pages), offset=8, count=count,
                                   wire_dtype=wd, interpret=True))
    raw = pages[:, 8:8 + esize * count].copy()
    if wd == "float32":
        assert np.array_equal(out.view("<u4"), raw.view("<f4").view("<u4"))
    elif wd == "int32":
        assert np.array_equal(out, raw.view("<i4"))
    elif wd == "uint16":
        assert np.array_equal(out, raw.view("<u2"))
    elif wd == "uint8":
        assert np.array_equal(out, raw)
    elif wd == "float16":
        assert np.allclose(out, raw.view("<f2").astype("<f4"), equal_nan=True)


def test_decode_multi_column_single_pass(rng):
    n, dim = 128, 32
    stride = 16 + 4 + 2 * dim  # uuid + u32 + bf16[dim] (4-aligned)
    pages = rng.integers(0, 255, (n, stride), dtype=np.uint8)
    outs = decode_columns(jnp.asarray(pages), fields=(
        (0, 16, "uint8", "uint8"),
        (16, 1, "uint32", "int32"),
        (20, dim, "bfloat16", "float32"),
    ), interpret=True)
    assert np.array_equal(np.asarray(outs[0]), pages[:, :16])
    assert np.array_equal(np.asarray(outs[1]).reshape(-1),
                          pages[:, 16:20].copy().view("<u4").reshape(-1)
                          .astype("<i4"))
    raw = pages[:, 20:].copy().view("<u2")
    # random bytes include NaN/Inf bit patterns: compare exact bits
    assert np.array_equal(np.asarray(outs[2]).view("<u4"),
                          raw.astype("<u4") << 16)


def test_device_layout_plan_and_decode(rng):
    """End-to-end: Bebop struct -> page -> device decode == host decode."""
    from repro.core import fastwire, pages as P, types as T
    from repro.core.device import decode_page_device, plan_device_layout
    seq = 32
    s = T.Struct("Ex", [T.Field("doc_id", T.UUID),
                        T.Field("tokens", T.FixedArray(T.UINT32, seq))])
    layout = plan_device_layout(s)
    assert layout.stride == 16 + 4 * seq
    recs = np.zeros(64, dtype=fastwire.static_dtype(s))
    recs["tokens"] = rng.integers(0, 2**31, (64, seq), dtype=np.uint32)
    page = P.write_page("Ex", recs)
    payload = P.read_payload(page, expect_schema="Ex")
    cols = decode_page_device(jnp.asarray(np.ascontiguousarray(payload)),
                              layout, impl="pallas")
    assert np.array_equal(np.asarray(cols["tokens"]),
                          recs["tokens"].astype("<i4"))


def test_misaligned_column_rejected():
    from repro.core import types as T
    from repro.core.device import plan_device_layout
    s = T.Struct("Bad", [T.Field("flag", T.BOOL),
                         T.Field("vals", T.FixedArray(T.UINT32, 4))])
    with pytest.raises(T.SchemaError):
        plan_device_layout(s)


def test_alignment_sort_fixes_layout():
    from repro.core import types as T
    from repro.core.device import plan_device_layout, sort_fields_for_alignment
    s = T.Struct("Bad", [T.Field("flag", T.BOOL),
                         T.Field("vals", T.FixedArray(T.UINT32, 4))])
    fixed = sort_fields_for_alignment(s)
    assert [f.name for f in fixed.fields] == ["vals", "flag"]
    plan_device_layout(fixed)  # no raise


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,t,s,d,causal,window", [
    (2, 4, 2, 128, 128, 64, True, None),
    (1, 8, 1, 128, 128, 32, True, None),     # MQA
    (2, 4, 4, 64, 128, 64, False, None),     # cross-ish
    (1, 4, 2, 128, 128, 64, True, 64),       # sliding window
    (1, 2, 2, 64, 64, 128, True, None),
])
def test_flash_attention_vs_ref(rng, b, hq, hkv, t, s, d, causal, window):
    q = rng.standard_normal((b, hq, t, d)).astype(np.float32)
    k = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
    v = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
    o1 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=causal, window=window, block_q=64,
                         block_k=64, interpret=True)
    o2 = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                       causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=3e-5, rtol=1e-4)


def test_flash_attention_decode_q1(rng):
    """Decode step: q length 1 against a 256-long KV history."""
    q = rng.standard_normal((2, 4, 1, 64)).astype(np.float32)
    k = rng.standard_normal((2, 2, 256, 64)).astype(np.float32)
    v = rng.standard_normal((2, 2, 256, 64)).astype(np.float32)
    o1 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=True, q_offset=255, block_q=1, block_k=64,
                         interpret=True)
    o2 = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                       causal=True, q_offset=255)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5,
                               rtol=1e-4)


# --------------------------------------------------------------------------
# paged attention (block-table KV gather)
# --------------------------------------------------------------------------

def _paged_setup(rng, b, hq, hkv, d, bs, m, n):
    q = rng.standard_normal((b, hq, d)).astype(np.float32)
    kp = rng.standard_normal((n, hkv, bs, d)).astype(np.float32)
    vp = rng.standard_normal((n, hkv, bs, d)).astype(np.float32)
    # distinct physical blocks per row, shuffled: the table is the ONLY
    # thing mapping logical order onto the pool
    tables = np.stack([rng.permutation(np.arange(1, n))[:m]
                       for _ in range(b)]).astype(np.int32)
    return q, kp, vp, tables


@pytest.mark.parametrize("b,hq,hkv,d,bs,m,n", [
    (4, 4, 2, 16, 8, 6, 32),
    (2, 8, 1, 64, 16, 4, 16),     # MQA
    (3, 4, 4, 32, 16, 8, 64),
    (1, 2, 2, 128, 32, 2, 8),
])
def test_paged_attention_vs_ref(rng, b, hq, hkv, d, bs, m, n):
    q, kp, vp, tables = _paged_setup(rng, b, hq, hkv, d, bs, m, n)
    ctx = rng.integers(1, m * bs + 1, b).astype(np.int32)
    out = paged_attention(jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                          jnp.asarray(tables), jnp.asarray(ctx),
                          interpret=True)
    expect = ref.paged_attention(jnp.asarray(q)[:, :, None, :],
                                 jnp.asarray(kp), jnp.asarray(vp),
                                 jnp.asarray(tables),
                                 jnp.asarray(ctx - 1)[:, None])
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(expect)[:, :, 0, :],
                               atol=3e-5, rtol=1e-4)


def test_paged_attention_matches_contiguous(rng):
    """Gathering through the block table == dense attention over the
    contiguous cache the table describes (per row, per context length)."""
    b, hq, hkv, d, bs, m, n = 4, 4, 2, 32, 8, 4, 32
    q, kp, vp, tables = _paged_setup(rng, b, hq, hkv, d, bs, m, n)
    ctx = np.array([1, 9, 17, 32], np.int32)
    out = np.asarray(paged_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
        jnp.asarray(tables), jnp.asarray(ctx), interpret=True))
    k = np.moveaxis(kp[tables], 2, 1).reshape(b, hkv, m * bs, d)
    v = np.moveaxis(vp[tables], 2, 1).reshape(b, hkv, m * bs, d)
    for i in range(b):
        dense = ref.attention(
            jnp.asarray(q[i:i + 1, :, None, :]),
            jnp.asarray(k[i:i + 1, :, :ctx[i]]),
            jnp.asarray(v[i:i + 1, :, :ctx[i]]),
            causal=True, q_offset=int(ctx[i]) - 1)
        np.testing.assert_allclose(out[i], np.asarray(dense)[0, :, 0],
                                   atol=3e-5, rtol=1e-4)


def test_paged_attention_ignores_unlisted_blocks(rng):
    """Pool contents outside a row's table must never leak into its
    output: scribbling over every unlisted block changes nothing."""
    b, hq, hkv, d, bs, m, n = 2, 4, 2, 16, 8, 4, 32
    q, kp, vp, tables = _paged_setup(rng, b, hq, hkv, d, bs, m, n)
    ctx = np.array([13, 29], np.int32)
    args = (jnp.asarray(tables), jnp.asarray(ctx))
    out1 = np.asarray(paged_attention(jnp.asarray(q), jnp.asarray(kp),
                                      jnp.asarray(vp), *args,
                                      interpret=True))
    listed = set(tables.reshape(-1).tolist())
    scrib_k, scrib_v = kp.copy(), vp.copy()
    for blk in range(n):
        if blk not in listed:
            scrib_k[blk] = 1e3
            scrib_v[blk] = -1e3
    out2 = np.asarray(paged_attention(jnp.asarray(q), jnp.asarray(scrib_k),
                                      jnp.asarray(scrib_v), *args,
                                      interpret=True))
    np.testing.assert_array_equal(out1, out2)


def test_paged_ref_prefill_chunk_shape(rng):
    """The reference path also serves chunked prefill (T > 1)."""
    b, hq, hkv, d, bs, m, n, t = 2, 4, 2, 16, 8, 4, 16, 8
    q = rng.standard_normal((b, hq, t, d)).astype(np.float32)
    _, kp, vp, tables = _paged_setup(rng, b, hq, hkv, d, bs, m, n)
    qpos = np.broadcast_to(np.arange(t, dtype=np.int32), (b, t))
    out = ref.paged_attention(jnp.asarray(q), jnp.asarray(kp),
                              jnp.asarray(vp), jnp.asarray(tables),
                              jnp.asarray(qpos))
    assert out.shape == (b, hq, t, d)
    assert np.isfinite(np.asarray(out)).all()


# --------------------------------------------------------------------------
# paged prefill attention (multi-token query tiles through the block table)
# --------------------------------------------------------------------------

def _prefill_setup(rng, b, hq, hkv, d, bs, m, n, t):
    q = rng.standard_normal((b, hq, t, d)).astype(np.float32)
    _, kp, vp, tables = _paged_setup(rng, b, hq, hkv, d, bs, m, n)
    return q, kp, vp, tables


@pytest.mark.parametrize("b,hq,hkv,d,bs,m,n,t", [
    (3, 4, 2, 16, 8, 4, 32, 8),
    (2, 8, 1, 64, 16, 4, 16, 16),   # MQA
    (1, 2, 2, 128, 32, 2, 8, 4),
    (2, 4, 4, 32, 16, 8, 64, 32),
])
def test_paged_prefill_vs_ref(rng, b, hq, hkv, d, bs, m, n, t):
    """Chunk tiles at per-row start offsets: kernel == reference gather."""
    q, kp, vp, tables = _prefill_setup(rng, b, hq, hkv, d, bs, m, n, t)
    starts = rng.integers(0, m * bs - t + 1, b)
    qpos = (starts[:, None] + np.arange(t)).astype(np.int32)
    out = paged_prefill_attention(jnp.asarray(q), jnp.asarray(kp),
                                  jnp.asarray(vp), jnp.asarray(tables),
                                  jnp.asarray(qpos), interpret=True)
    expect = ref.paged_attention(jnp.asarray(q), jnp.asarray(kp),
                                 jnp.asarray(vp), jnp.asarray(tables),
                                 jnp.asarray(qpos))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=3e-5, rtol=1e-4)


def test_paged_prefill_mixed_rows_vs_ref(rng):
    """The mixed-step shape: decode rows padded to the chunk width with
    repeated positions alongside genuinely prefilling rows — one call."""
    b, hq, hkv, d, bs, m, n, t = 4, 4, 2, 16, 8, 4, 32, 8
    q, kp, vp, tables = _prefill_setup(rng, b, hq, hkv, d, bs, m, n, t)
    qpos = np.stack([
        np.full(t, 19),            # decode row, ctx 20, t-1 pad duplicates
        5 + np.arange(t),          # prefill chunk at offset 5
        np.full(t, 0),             # decode row at the very first position
        np.arange(t),              # prefill chunk from position 0
    ]).astype(np.int32)
    out = paged_prefill_attention(jnp.asarray(q), jnp.asarray(kp),
                                  jnp.asarray(vp), jnp.asarray(tables),
                                  jnp.asarray(qpos), interpret=True)
    expect = ref.paged_attention(jnp.asarray(q), jnp.asarray(kp),
                                 jnp.asarray(vp), jnp.asarray(tables),
                                 jnp.asarray(qpos))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=3e-5, rtol=1e-4)
    # a padded decode row agrees with the T == 1 decode kernel at token 0
    dec = paged_attention(jnp.asarray(q[:1, :, 0, :]), jnp.asarray(kp),
                          jnp.asarray(vp), jnp.asarray(tables[:1]),
                          jnp.asarray(np.array([20], np.int32)),
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out)[0, :, 0, :],
                               np.asarray(dec)[0], atol=3e-5, rtol=1e-4)


def test_paged_prefill_matches_flash_on_contiguous(rng):
    """Gathering chunk tiles through the block table == flash attention
    over the contiguous cache the table describes (per row)."""
    b, hq, hkv, d, bs, m, n, t = 3, 4, 2, 32, 8, 4, 32, 8
    q, kp, vp, tables = _prefill_setup(rng, b, hq, hkv, d, bs, m, n, t)
    ctx = np.array([16, 24, 32], np.int32)       # history INCLUDING chunk
    qpos = (ctx[:, None] - t + np.arange(t)).astype(np.int32)
    out = np.asarray(paged_prefill_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
        jnp.asarray(tables), jnp.asarray(qpos), interpret=True))
    k = np.moveaxis(kp[tables], 2, 1).reshape(b, hkv, m * bs, d)
    v = np.moveaxis(vp[tables], 2, 1).reshape(b, hkv, m * bs, d)
    for i in range(b):
        flash = flash_attention(
            jnp.asarray(q[i:i + 1]), jnp.asarray(k[i:i + 1, :, :ctx[i]]),
            jnp.asarray(v[i:i + 1, :, :ctx[i]]), causal=True,
            q_offset=int(ctx[i]) - t, block_q=t, block_k=bs,
            interpret=True)
        np.testing.assert_allclose(out[i], np.asarray(flash)[0],
                                   atol=3e-5, rtol=1e-4)


def test_paged_prefill_ignores_unlisted_blocks(rng):
    """Same isolation contract as decode: scribbling over every block not
    listed in a row's table changes nothing."""
    b, hq, hkv, d, bs, m, n, t = 2, 4, 2, 16, 8, 4, 32, 8
    q, kp, vp, tables = _prefill_setup(rng, b, hq, hkv, d, bs, m, n, t)
    qpos = np.stack([3 + np.arange(t), 11 + np.arange(t)]).astype(np.int32)
    args = (jnp.asarray(tables), jnp.asarray(qpos))
    out1 = np.asarray(paged_prefill_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), *args,
        interpret=True))
    listed = set(tables.reshape(-1).tolist())
    scrib_k, scrib_v = kp.copy(), vp.copy()
    for blk in range(n):
        if blk not in listed:
            scrib_k[blk] = 1e3
            scrib_v[blk] = -1e3
    out2 = np.asarray(paged_prefill_attention(
        jnp.asarray(q), jnp.asarray(scrib_k), jnp.asarray(scrib_v), *args,
        interpret=True))
    np.testing.assert_array_equal(out1, out2)


def test_ops_paged_dispatch_prefill_pallas(rng):
    """ops.paged_attention T > 1 runs the Pallas prefill kernel (no more
    reference fallback) and agrees with the reference path."""
    from repro.kernels import ops
    b, hq, hkv, d, bs, m, n, t = 2, 4, 2, 16, 8, 4, 16, 8
    q, kp, vp, tables = _prefill_setup(rng, b, hq, hkv, d, bs, m, n, t)
    qpos = np.stack([np.arange(t), 7 + np.arange(t)]).astype(np.int32)
    args = tuple(map(jnp.asarray, (q, kp, vp, tables, qpos)))
    out_pl = ops.paged_attention(*args, impl="pallas")
    out_ref = ops.paged_attention(*args, impl="reference")
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref),
                               atol=3e-5, rtol=1e-4)


def test_flash_attention_bf16(rng):
    q = rng.standard_normal((1, 2, 64, 64)).astype(jnp.bfloat16)
    k = rng.standard_normal((1, 2, 64, 64)).astype(jnp.bfloat16)
    v = rng.standard_normal((1, 2, 64, 64)).astype(jnp.bfloat16)
    o1 = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    o2 = ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o1, dtype=np.float32),
                               np.asarray(o2, dtype=np.float32),
                               atol=3e-2, rtol=3e-2)


# --------------------------------------------------------------------------
# rwkv6
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,t,kk,vv,chunk", [
    (1, 1, 32, 16, 16, 8),
    (2, 2, 64, 32, 32, 16),
    (1, 4, 128, 64, 64, 64),
])
def test_rwkv6_vs_ref(rng, b, h, t, kk, vv, chunk):
    r = rng.standard_normal((b, h, t, kk)).astype(np.float32) * 0.5
    k = rng.standard_normal((b, h, t, kk)).astype(np.float32) * 0.5
    v = rng.standard_normal((b, h, t, vv)).astype(np.float32) * 0.5
    w = np.exp(-np.exp(rng.standard_normal((b, h, t, kk)))).astype(np.float32)
    u = (rng.standard_normal((h, kk)) * 0.3).astype(np.float32)
    o1, s1 = rwkv6_scan(*map(jnp.asarray, (r, k, v, w, u)), chunk=chunk,
                        interpret=True)
    o2, s2 = ref.rwkv6(*map(jnp.asarray, (r, k, v, w, u)))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_rwkv6_state_continuity(rng):
    """Scanning two halves with carried state == one full scan."""
    b, h, t, d = 1, 2, 64, 32
    r, k, w = (rng.standard_normal((b, h, t, d)).astype(np.float32) * 0.4
               for _ in range(3))
    w = np.exp(-np.exp(w))
    v = rng.standard_normal((b, h, t, d)).astype(np.float32) * 0.4
    u = (rng.standard_normal((h, d)) * 0.3).astype(np.float32)
    o_full, s_full = ref.rwkv6(*map(jnp.asarray, (r, k, v, w, u)))
    o1, s1 = ref.rwkv6(r[:, :, :32], k[:, :, :32], v[:, :, :32],
                       w[:, :, :32], u)
    o2, s2 = ref.rwkv6(r[:, :, 32:], k[:, :, 32:], v[:, :, 32:],
                       w[:, :, 32:], u, initial_state=s1)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)
    np.testing.assert_allclose(
        np.concatenate([np.asarray(o1), np.asarray(o2)], axis=2),
        np.asarray(o_full), atol=1e-4)


# --------------------------------------------------------------------------
# rg-lru
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,d,chunk", [
    (1, 32, 16, 8), (2, 128, 64, 32), (1, 256, 128, 256),
])
def test_rglru_vs_ref(rng, b, t, d, chunk):
    x = rng.standard_normal((b, t, d)).astype(np.float32)
    a = 1.0 / (1.0 + np.exp(-rng.standard_normal((b, t, d)))).astype(
        np.float32)
    h1, f1 = rglru_scan(jnp.asarray(x), jnp.asarray(a), chunk=chunk,
                        interpret=True)
    h2, f2 = ref.rglru(jnp.asarray(x), jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-5)


def test_rglru_decay_bounds(rng):
    """With a == 1 the state is a running sum; with a == 0 it's identity."""
    x = rng.standard_normal((1, 16, 8)).astype(np.float32)
    ones = np.ones_like(x)
    h_sum, _ = ref.rglru(jnp.asarray(x), jnp.asarray(ones))
    np.testing.assert_allclose(np.asarray(h_sum), np.cumsum(x, axis=1),
                               atol=1e-5)
    h_id, _ = ref.rglru(jnp.asarray(x), jnp.asarray(np.zeros_like(x)))
    np.testing.assert_allclose(np.asarray(h_id), x, atol=1e-6)


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_rwkv6_chunked_matches_sequential(rng, chunk):
    """The §Perf chunked WKV reformulation is numerically equivalent."""
    B, H, T, K, V = 2, 2, 128, 32, 32
    r = rng.standard_normal((B, H, T, K)).astype(np.float32) * 0.5
    k = rng.standard_normal((B, H, T, K)).astype(np.float32) * 0.5
    v = rng.standard_normal((B, H, T, V)).astype(np.float32) * 0.5
    wlog = rng.uniform(-6, 0.5, (B, H, T, K)).astype(np.float32)
    w = np.exp(-np.exp(wlog))
    u = (rng.standard_normal((H, K)) * 0.3).astype(np.float32)
    o1, s1 = ref.rwkv6(*map(jnp.asarray, (r, k, v, w, u)))
    o2, s2 = ref.rwkv6_chunked(*map(jnp.asarray, (r, k, v, w, u)),
                               chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)


def test_rwkv6_chunked_model_loss_matches(rng):
    """Full model: chunked impl gives the same loss as sequential."""
    import dataclasses
    import jax as _jax
    from repro.configs import get_config, reduced_config
    from repro.models import get_model
    cfg = reduced_config(get_config("rwkv6-7b"))
    cfg_seq = dataclasses.replace(cfg, rwkv_impl="sequential")
    cfg_chk = dataclasses.replace(cfg, rwkv_impl="chunked", rwkv_chunk=8)
    m1, m2 = get_model(cfg_seq), get_model(cfg_chk)
    params = m1.init(_jax.random.PRNGKey(0))
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, 16))
             .astype(np.int32),
             "labels": rng.integers(0, cfg.vocab_size, (2, 16))
             .astype(np.int32)}
    l1 = float(m1.loss(params, batch))
    l2 = float(m2.loss(params, batch))
    assert abs(l1 - l2) < 1e-3, (l1, l2)
