"""benchmarks/run.py perf-trajectory comparison: the artifact is a gate."""
import json

from benchmarks.run import (COMPARE_FLOOR_US, compare_to_baseline,
                            load_baseline, write_json)


def _doc(medians, *, quick=True, created="2026-01-01T00:00:00Z",
         sha="abc1234"):
    return {
        "git_sha": sha, "created_utc": created, "quick": quick,
        "benchmarks": [{"name": n, "median": m, "units": "us_per_call",
                        "derived": ""} for n, m in medians.items()],
    }


def test_compare_reports_deltas_and_regressions():
    base = _doc({"a.fast": 100.0, "b.slow": 10.0, "c.gone": 5.0})
    rows = [("a.fast", 90.0, ""),        # improved
            ("b.slow", 16.0, ""),        # 1.6x: regression
            ("d.new", 42.0, "")]         # no baseline: skipped
    deltas, regressions = compare_to_baseline(rows, base, 1.5)
    assert [d[0] for d in deltas] == ["a.fast", "b.slow"]
    assert [r[0] for r in regressions] == ["b.slow"]
    name, old, new, ratio = regressions[0]
    assert (old, new) == (10.0, 16.0) and abs(ratio - 1.6) < 1e-9


def test_compare_threshold_is_inclusive_boundary():
    base = _doc({"x": 10.0})
    _, regressions = compare_to_baseline([("x", 15.0, "")], base, 1.5)
    assert not regressions                      # exactly 1.5x passes
    _, regressions = compare_to_baseline([("x", 15.01, "")], base, 1.5)
    assert regressions


def test_compare_ignores_noise_floor_rows():
    tiny = COMPARE_FLOOR_US / 4
    base = _doc({"ns.scale": tiny, "real": 100.0})
    deltas, regressions = compare_to_baseline(
        [("ns.scale", tiny * 2, ""), ("real", 100.0, "")], base, 1.5)
    assert [d[0] for d in deltas] == ["real"]   # both sub-floor: skipped
    assert not regressions


def test_compare_subfloor_to_slow_is_still_a_regression():
    """The floor must not hide a benchmark that regresses from noise-level
    to genuinely slow."""
    base = _doc({"x": COMPARE_FLOOR_US / 2})
    _, regressions = compare_to_baseline([("x", 900.0, "")], base, 1.5)
    assert [r[0] for r in regressions] == ["x"]


def test_compare_subfloor_baseline_jitter_does_not_fail():
    """A sub-floor baseline is measured against the floor, so dispatch
    jitter just above 1us never reads as a 1.5x regression."""
    base = _doc({"x": 0.9})
    _, regressions = compare_to_baseline(
        [("x", COMPARE_FLOOR_US * 1.4, "")], base, 1.5)
    assert not regressions


def test_load_baseline_latest_committed_excluding_current(tmp_path):
    old = tmp_path / "BENCH_old.json"
    new = tmp_path / "BENCH_new.json"
    cur = tmp_path / "BENCH_cur.json"
    old.write_text(json.dumps(_doc({"a": 1.0},
                                   created="2026-01-01T00:00:00Z")))
    new.write_text(json.dumps(_doc({"a": 2.0},
                                   created="2026-02-01T00:00:00Z")))
    cur.write_text(json.dumps(_doc({"a": 3.0},
                                   created="2026-03-01T00:00:00Z")))
    path, doc = load_baseline(str(tmp_path), str(cur), quick=True)
    assert path == str(new)                     # latest, never itself
    assert doc["benchmarks"][0]["median"] == 2.0


def test_load_baseline_skips_other_quick_mode_and_garbage(tmp_path):
    (tmp_path / "BENCH_full.json").write_text(
        json.dumps(_doc({"a": 1.0}, quick=False)))
    (tmp_path / "BENCH_bad.json").write_text("{not json")
    path, doc = load_baseline(str(tmp_path), str(tmp_path / "none.json"),
                              quick=True)
    assert path is None and doc is None
    path, doc = load_baseline(str(tmp_path), str(tmp_path / "none.json"),
                              quick=False)
    assert path == str(tmp_path / "BENCH_full.json")


def test_write_json_roundtrips_through_load(tmp_path):
    rows = [("k.bench", 12.345, "speedup=2.0x")]
    out = tmp_path / "BENCH_cafe.json"
    write_json(rows, str(out), quick=True)
    path, doc = load_baseline(str(tmp_path), str(tmp_path / "other.json"),
                              quick=True)
    assert path == str(out)
    deltas, regressions = compare_to_baseline(
        [("k.bench", 12.345, "")], doc, 1.5)
    assert deltas and not regressions
