"""bebopc CLI (§6.1) end-to-end."""
import os
import subprocess
import sys

SCHEMA = """
edition = "2026"
package demo
struct Point { x: float32; y: float32; }
message Meta { note(1): string; }
service Geo { Locate(Point): Point; Track(Point): stream Point; }
"""


def _run(args, cwd):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    return subprocess.run(
        [sys.executable, "-m", "repro.core.cli", *args],
        cwd=cwd, env=env, capture_output=True, text=True, timeout=120)


def test_cli_check_build_ids(tmp_path):
    bop = tmp_path / "demo.bop"
    bop.write_text(SCHEMA)
    r = _run(["check", "demo.bop"], tmp_path)
    assert r.returncode == 0 and "OK" in r.stdout

    r = _run(["ids", "demo.bop"], tmp_path)
    assert r.returncode == 0
    assert "/Geo/Locate" in r.stdout and "server_stream" in r.stdout

    r = _run(["build", "demo.bop", "--python-out", "gen",
              "--descriptor-out", "demo.bin"], tmp_path)
    assert r.returncode == 0, r.stderr
    gen = tmp_path / "gen" / "demo_bebop.py"
    assert gen.is_file()
    assert (tmp_path / "demo.bin").stat().st_size > 0

    # the generated module is importable and round-trips
    code = ("import demo_bebop as d\n"
            "p = d.Point(x=1.5, y=-2.0)\n"
            "q = d.Point.decode(p.encode())\n"
            "assert q.x == 1.5 and q.y == -2.0\n"
            "m = d.Meta(note='hi')\n"
            "assert d.Meta.decode(m.encode()).note == 'hi'\n"
            "print('ok')\n")
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + str(tmp_path / "gen")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr
    assert "ok" in r.stdout


def test_cli_reports_errors(tmp_path):
    bop = tmp_path / "bad.bop"
    bop.write_text("struct S { x: not_a_type; }")
    r = _run(["check", "bad.bop"], tmp_path)
    assert r.returncode == 1
    assert "error" in r.stderr
