"""The CI perf-gate module: pass/fail/missing-row behavior.

The gates used to be inline heredoc scripts in the workflow YAML —
unreviewable and untestable.  These tests pin the contract the workflow
now relies on: a good CSV exits 0, any threshold miss or missing row
exits 1 with a readable report, and the step summary carries both the
gate results and the full bench table.
"""
from benchmarks import check_gates as cg

GOOD_ROWS = """\
name,us_per_call,derived
serve_ingest.host_parse.4096B,100.0,baseline
serve_ingest.device_decode.4096B,40.0,speedup=2.50x cv=0.01
serve_ingest.device_decode.16384B,30.0,speedup=3.40x cv=0.01
serve_ingest.device_decode.1024B,80.0,speedup=1.20x below-gate-size
paged_attention.decode_step.b4.dense,400.0,4x batch-1 calls cv=0.02
paged_attention.decode_step.b4.paged,250.0,speedup=1.60x cv=0.02
paged_attention.engine_mixed16.paged,900.0,tokens_per_s=80.0 speedup=3.10x
paged_attention.mixed_admission.fused,120.0,p99=300us ratio=0.12x vs blocking
paged_attention.shared_prefix.cached,500.0,speedup=6.00x ttft_p50=1.2ms prefix_hits=16 prefix_tokens_reused=8192 cow_copies=0
paged_attention.spec_decode.on,700.0,tokens_per_s=500.0 speedup=1.80x accept_rate=0.95 spec_proposed=520 spec_accepted=492
paged_attention.sampling.serial,9000.0,tokens_per_s=14.0 one dense sampled request at a time
paged_attention.sampling.batched,3000.0,tokens_per_s=42.0 speedup=3.00x sampled_requests=16
paged_attention.parallel_n.independent,5000.0,peak_blocks=20 4 separate submissions of one 64-token prompt
paged_attention.parallel_n.forked,2000.0,block_ratio=2.50 peak_blocks=8 speedup=2.50x forks=3 cow_copies=4
paged_attention.overload.shed_only,60000.0,goodput=3 of 11 reqs at a 0.35x-ref burst deadline
paged_attention.overload.swap,80000.0,goodput=11 goodput_ratio=3.67x preemptions=4 swapped_blocks=20 swap_ins=4 slo_violations=0
paged_attention.failover.baseline,900000.0,goodput=20.0 req_per_s completed=18 of 18 (3 replicas no failure)
paged_attention.failover.killed,1100000.0,goodput_ratio=0.82 completed=18 of 18 duplicates=0 corrupted=0 failovers=5 (one replica killed mid-run)
paged_attention.hedged_tail.unhedged,550000.0,p50=520.0ms p99=550.0ms one replica behind a 250ms one-way link
paged_attention.hedged_tail.hedged,120000.0,p99_ratio=0.22 p50=60.0ms p99=120.0ms hedges_fired=6 hedges_won=5
"""


def _write(tmp_path, text):
    p = tmp_path / "bench.csv"
    p.write_text(text)
    return str(p)


def test_all_gates_pass(tmp_path):
    rows = cg.parse_rows(_write(tmp_path, GOOD_ROWS))
    results = cg.check(rows)
    assert results and all(r.ok for r in results)
    assert cg.main([_write(tmp_path, GOOD_ROWS)]) == 0


def test_threshold_miss_fails_with_readable_report(tmp_path):
    bad = GOOD_ROWS.replace("speedup=1.80x accept_rate",
                            "speedup=1.10x accept_rate")
    rows = cg.parse_rows(_write(tmp_path, bad))
    results = cg.check(rows)
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert failed[0].gate == "speculative decode"
    assert "1.10" in failed[0].detail and "1.3" in failed[0].detail
    report = cg.render_report(results)
    assert "[FAIL] speculative decode" in report
    assert cg.main([_write(tmp_path, bad)]) == 1


def test_missing_row_is_a_failure_not_a_crash(tmp_path):
    # drop the whole shared_prefix row: its gate must FAIL and name the
    # missing row, and every other gate must still be evaluated
    lines = [ln for ln in GOOD_ROWS.splitlines()
             if not ln.startswith("paged_attention.shared_prefix")]
    rows = cg.parse_rows(_write(tmp_path, "\n".join(lines) + "\n"))
    results = cg.check(rows)
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert "missing" in failed[0].detail
    assert "shared_prefix" in failed[0].detail
    assert any(r.gate == "speculative decode" and r.ok for r in results)
    assert cg.main([_write(tmp_path, "\n".join(lines) + "\n")]) == 1


def test_zero_acceptance_fails_even_with_speedup(tmp_path):
    bad = GOOD_ROWS.replace("spec_accepted=492", "spec_accepted=0")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert "spec_accepted=0" in failed[0].detail


def test_sampling_speedup_miss_fails(tmp_path):
    bad = GOOD_ROWS.replace("speedup=3.00x sampled_requests",
                            "speedup=1.05x sampled_requests")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert failed[0].gate == "seeded sampling throughput"
    assert "1.05" in failed[0].detail and "1.2" in failed[0].detail


def test_sampling_zero_sampled_requests_fails(tmp_path):
    # a speedup with nothing sampled means the workload degenerated to
    # greedy (e.g. a default temperature of 0 leaked through)
    bad = GOOD_ROWS.replace("sampled_requests=16", "sampled_requests=0")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert "sampled_requests=0" in failed[0].detail


def test_parallel_n_block_ratio_miss_fails(tmp_path):
    bad = GOOD_ROWS.replace("block_ratio=2.50", "block_ratio=1.10")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert failed[0].gate == "parallel sampling KV sharing"
    assert "1.10" in failed[0].detail and "1.5" in failed[0].detail


def test_parallel_n_zero_forks_fails_even_with_ratio(tmp_path):
    bad = GOOD_ROWS.replace("forks=3", "forks=0")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert "forks=0" in failed[0].detail


def test_overload_ratio_miss_fails(tmp_path):
    bad = GOOD_ROWS.replace("goodput_ratio=3.67x", "goodput_ratio=1.20x")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert failed[0].gate == "overload goodput (swap vs shed)"
    assert "1.20" in failed[0].detail and "1.5" in failed[0].detail


def test_overload_no_preemption_fails_even_with_ratio(tmp_path):
    # a goodput ratio without any actual host round-trip means the
    # workload degenerated (e.g. the pool was never oversubscribed)
    bad = GOOD_ROWS.replace("preemptions=4 swapped_blocks=20 swap_ins=4",
                            "preemptions=0 swapped_blocks=0 swap_ins=0")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert "preemptions=0" in failed[0].detail


def test_failover_ratio_miss_fails(tmp_path):
    bad = GOOD_ROWS.replace("goodput_ratio=0.82", "goodput_ratio=0.40")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert failed[0].gate == "failover goodput (replica kill)"
    assert "0.40" in failed[0].detail and "0.6" in failed[0].detail


def test_failover_duplicates_fail_even_with_goodput(tmp_path):
    bad = GOOD_ROWS.replace("duplicates=0 corrupted=0",
                            "duplicates=1 corrupted=0")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert "duplicates=1" in failed[0].detail


def test_hedged_tail_ratio_miss_fails(tmp_path):
    bad = GOOD_ROWS.replace("p99_ratio=0.22", "p99_ratio=0.80")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert failed[0].gate == "hedged tail latency"
    assert "0.80" in failed[0].detail and "0.5" in failed[0].detail


def test_hedged_tail_no_wins_fails_even_with_ratio(tmp_path):
    # a good p99 ratio with zero rescued attempts means the workload
    # degenerated (e.g. the slow replica was never routed to at all)
    bad = GOOD_ROWS.replace("hedges_won=5", "hedges_won=0")
    results = cg.check(cg.parse_rows(_write(tmp_path, bad)))
    failed = [r for r in results if not r.ok]
    assert len(failed) == 1
    assert "hedges_won=0" in failed[0].detail


def test_error_rows_with_commas_parse_as_derived(tmp_path):
    text = GOOD_ROWS + \
        "kernels.ERROR,0,ImportError('no pallas', 'extra, comma')\n"
    rows = cg.parse_rows(_write(tmp_path, text))
    assert "ImportError" in rows["kernels.ERROR"][1]
    assert "extra, comma" in rows["kernels.ERROR"][1]


def test_step_summary_written(tmp_path, monkeypatch):
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert cg.main([_write(tmp_path, GOOD_ROWS)]) == 0
    text = summary.read_text()
    assert "## Perf gates" in text
    assert "speculative decode" in text
    # the full bench table rides along for the per-run trajectory
    assert "paged_attention.spec_decode.on" in text
    assert "✅" in text and "❌" not in text


def test_usage_error():
    assert cg.main([]) == 2
    assert cg.main(["a.csv", "b.csv"]) == 2
