"""The wire->device serving path, end to end.

A page-encoded Infer request travels: client page encode -> RPC -> header
validation -> raw device placement -> bebop_decode kernel -> continuous
batcher -> engine -> page-encoded response.  The host never parses a
token; these tests assert the result is bit-identical to the host-parse
reference path (Generate over the same prompt).
"""

import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import pages, wire
from repro.core.rpc import (Channel, Deadline, RpcError, Status,
                            connected_pair)
from repro.serving import (ContinuousBatcher, Engine, PageIngest,
                           ServeConfig, ShedError, build_server,
                           decode_token_page, encode_prompt_page)
from repro.serving.service import (InferChunk, InferenceService,
                                   InferRequest, ScoreResponse,
                                   prompt_record_struct)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen2-1.5b"))
    engine = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=8))
    server = build_server(engine)
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    yield cfg, engine, ch
    ch.close()


def _prompt(cfg, b=1, t=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (b, t)).astype(np.uint32)


# -- end-to-end: page path == host path ---------------------------------------

def test_infer_page_matches_host_reference(setup):
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    p = _prompt(cfg, b=2)
    res = inf.Infer({"page": encode_prompt_page(p), "max_new_tokens": 4})
    assert res["batch"] == 2 and res["new_tokens"] == 4
    out = decode_token_page(bytes(bytearray(res["page"])))
    # host-parse reference: same prompt through the non-page RPC method
    ref = inf.Generate({"tokens": p.reshape(-1), "batch": 2, "seq_len": 8,
                        "max_new_tokens": 4})
    assert np.array_equal(out.reshape(-1),
                          np.asarray(ref["tokens"], np.uint32))
    # and against the engine directly (greedy argmax over the logits)
    direct = engine.generate(p.astype(np.int32), max_new_tokens=4)
    assert np.array_equal(out.astype(np.int32), direct)


def test_infer_stream_cursor_resume(setup):
    cfg, engine, ch = setup
    sid = InferenceService.method("InferStream").id
    p = _prompt(cfg, seed=3)
    req = wire.encode(InferRequest,
                      {"page": encode_prompt_page(p), "max_new_tokens": 6})
    it = ch.call(sid, req, server_stream=True)
    got, cursor = [], 0
    for item in it:
        chunk = wire.decode(InferChunk, item.payload)
        got.extend(decode_token_page(
            bytes(bytearray(chunk["page"]))).reshape(-1))
        cursor = item.cursor
        if chunk["index"] == 2:
            break
    it2 = ch.call(sid, req, server_stream=True, cursor=cursor)
    for item in it2:
        chunk = wire.decode(InferChunk, item.payload)
        got.extend(decode_token_page(
            bytes(bytearray(chunk["page"]))).reshape(-1))
    ref = engine.generate(p.astype(np.int32), max_new_tokens=6)
    assert [int(x) for x in got] == [int(x) for x in ref.reshape(-1)]


def test_infer_scorepage_batch_pipeline(setup):
    """Prefill -> decode -> score resolves server-side in ONE round trip."""
    cfg, engine, ch = setup
    iid = InferenceService.method("Infer").id
    sid = InferenceService.method("ScorePage").id
    p = _prompt(cfg, b=2, seed=5)
    res = ch.batch([
        {"method_id": iid, "payload": wire.encode(
            InferRequest, {"page": encode_prompt_page(p),
                           "max_new_tokens": 4})},
        {"method_id": sid, "input_from": 0},
    ])
    assert [r["status"] for r in res] == [Status.OK] * 2
    scores = wire.decode(ScoreResponse, res[1]["payload"])["scores"]
    assert len(scores) == 2 and np.all(np.isfinite(scores))


def test_infer_rejects_corrupt_page(setup):
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    page = bytearray(encode_prompt_page(_prompt(cfg)))
    page[pages.HEADER_SIZE + 2] ^= 0xAA
    with pytest.raises(RpcError) as ei:
        inf.Infer({"page": bytes(page), "max_new_tokens": 2})
    assert ei.value.code == Status.INVALID_ARGUMENT
    with pytest.raises(RpcError) as ei:
        inf.Infer({"max_new_tokens": 2})  # no page at all
    assert ei.value.code == Status.INVALID_ARGUMENT


def test_infer_deadline_shedding(setup):
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    with pytest.raises(RpcError) as ei:
        inf.Infer({"page": encode_prompt_page(_prompt(cfg)),
                   "max_new_tokens": 4}, deadline=Deadline.after(-1))
    assert ei.value.code == Status.DEADLINE_EXCEEDED


# -- scheduler ----------------------------------------------------------------

def test_batcher_assembles_concurrent_requests(setup):
    cfg, engine, _ = setup
    batcher = ContinuousBatcher(engine, max_batch=8, window_s=0.25)
    prompts = [_prompt(cfg, seed=10 + i).astype(np.int32) for i in range(4)]
    futs = [batcher.submit(p, max_new_tokens=3) for p in prompts]
    outs = [f.result(timeout=120) for f in futs]
    # per-request results match solo generation (rows are independent)
    for p, o in zip(prompts, outs):
        assert o.shape == (1, 3)
        assert np.array_equal(o, engine.generate(p, max_new_tokens=3))
    st = batcher.stats
    assert st["requests"] == 4
    assert st["batches"] < st["requests"]  # at least one merged batch
    assert batcher.mean_batch_rows() > 1.0
    batcher.close()


def test_batcher_sheds_expired(setup):
    cfg, engine, _ = setup
    batcher = ContinuousBatcher(engine, max_batch=4, window_s=0.0)
    fut = batcher.submit(_prompt(cfg).astype(np.int32),
                         max_new_tokens=2, deadline=Deadline.after(-1))
    with pytest.raises(ShedError):
        fut.result(timeout=10)
    assert batcher.stats["shed"] == 1
    batcher.close()


def test_batcher_respects_per_request_max_new(setup):
    cfg, engine, _ = setup
    batcher = ContinuousBatcher(engine, max_batch=8, window_s=0.25)
    f_short = batcher.submit(_prompt(cfg, seed=20).astype(np.int32),
                             max_new_tokens=2)
    f_long = batcher.submit(_prompt(cfg, seed=21).astype(np.int32),
                            max_new_tokens=5)
    assert f_short.result(timeout=120).shape == (1, 2)
    assert f_long.result(timeout=120).shape == (1, 5)
    batcher.close()


# -- ingest unit --------------------------------------------------------------

def test_ingest_plan_cache_and_stats(setup):
    cfg, engine, _ = setup
    ing = PageIngest()
    s = prompt_record_struct(8)
    ing.register(s)
    p = _prompt(cfg, b=3)  # 3 records: exercises non-pow2 padding
    page = encode_prompt_page(p)
    res = ing.admit(page, expect_schema=s.name)
    assert res.record_count == 3
    assert np.array_equal(np.asarray(res.columns["tokens"]),
                          p.astype(np.int32))
    ing.admit(page)
    assert ing.cache.hits == 2 and ing.cache.misses == 0
    assert ing.stats["pages"] == 2 and ing.stats["records"] == 6

    # unknown schema is a miss + rejection
    other = encode_prompt_page(_prompt(cfg, t=16))
    with pytest.raises(pages.PageError):
        ing.admit(other)
    assert ing.cache.misses == 1
    assert ing.stats["rejected"] == 1


def test_ingest_stream_cursor(setup):
    cfg, engine, _ = setup
    ing = PageIngest()
    s = prompt_record_struct(8)
    ing.register(s)
    from repro.core.fastwire import static_dtype
    tok = _prompt(cfg, b=8, seed=7)
    recs = np.zeros(8, dtype=static_dtype(s))
    recs["tokens"] = tok.astype("<u4")
    buf = pages.write_page(s.name, recs[:4], first_record=0) + \
        pages.write_page(s.name, recs[4:], first_record=4)
    got = list(ing.admit_stream(buf, cursor=4))
    assert len(got) == 1  # first page skipped wholesale
    assert np.array_equal(np.asarray(got[0].columns["tokens"]),
                          tok[4:].astype(np.int32))
