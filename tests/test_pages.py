"""core/pages.py error paths and durability features.

Pages are the unit of wire->device transfer, so the failure modes matter as
much as the happy path: a corrupt payload must be detected before it
reaches a model, compression must round-trip bit-exactly, and the
``first_record`` cursor must let a reader resume mid-stream by skipping
whole pages.
"""
import numpy as np
import pytest

from repro.core import pages
from repro.core.hashing import schema_hash
from repro.data import pack_examples, synthetic_corpus, train_example_struct


SEQ = 8


def _page(n=16, first_record=0, seed=0, compress=False):
    s = train_example_struct(SEQ)
    toks = synthetic_corpus(SEQ, n, 997, seed=seed)
    recs = pack_examples(SEQ, toks)
    return s, toks, pages.write_page(s.name, recs, first_record=first_record,
                                     compress=compress)


# -- corruption ---------------------------------------------------------------

def test_corrupt_payload_crc_raises():
    s, _, buf = _page()
    bad = bytearray(buf)
    bad[pages.HEADER_SIZE + 3] ^= 0x5A
    with pytest.raises(pages.PageError, match="CRC"):
        pages.read_payload(bytes(bad))
    # verify=False skips the check (trusted-storage fast path)
    out = pages.read_payload(bytes(bad), verify=False)
    assert out.shape[0] == 16


def test_corrupt_header_crc_field_raises():
    s, _, buf = _page()
    bad = bytearray(buf)
    bad[20] ^= 0xFF  # payload_crc32 field inside the header
    with pytest.raises(pages.PageError, match="CRC"):
        pages.read_payload(bytes(bad))


def test_bad_magic_and_version():
    _, _, buf = _page()
    bad = bytearray(buf)
    bad[0] ^= 1
    with pytest.raises(pages.PageError, match="magic"):
        pages.read_header(bytes(bad))
    bad = bytearray(buf)
    bad[4] = 99
    with pytest.raises(pages.PageError, match="version"):
        pages.read_header(bytes(bad))


def test_truncated_header_and_payload():
    _, _, buf = _page()
    with pytest.raises(pages.PageError, match="truncated"):
        pages.read_header(buf[:32])
    with pytest.raises(pages.PageError, match="truncated"):
        pages.read_payload(buf[:pages.HEADER_SIZE + 8])


def test_schema_mismatch():
    s, _, buf = _page()
    assert pages.read_header(buf).schema_hash == schema_hash(s.name)
    with pytest.raises(pages.PageError, match="schema"):
        pages.read_payload(buf, expect_schema="SomethingElse")


# -- compression --------------------------------------------------------------

def test_compressed_roundtrip():
    zstd = pytest.importorskip("zstandard")  # noqa: F841 - optional dep
    s, toks, buf = _page(compress=True)
    h = pages.read_header(buf)
    assert h.compressed
    recs = pages.decode_page(s, buf)
    assert np.array_equal(recs["tokens"], toks)
    # corruption inside the compressed payload still surfaces as PageError
    bad = bytearray(buf)
    bad[pages.HEADER_SIZE + 1] ^= 0xFF
    with pytest.raises(Exception):
        pages.decode_page(s, bytes(bad))


# -- cursor resume ------------------------------------------------------------

def test_seek_cursor_skips_whole_pages():
    s, toks_a, page_a = _page(n=16, first_record=0, seed=1)
    _, toks_b, page_b = _page(n=16, first_record=16, seed=2)
    _, toks_c, page_c = _page(n=16, first_record=32, seed=3)
    buf = page_a + page_b + page_c
    offs = list(pages.iter_pages(buf))
    assert len(offs) == 3

    # cursor inside the second page: first page is skipped entirely
    off = pages.seek_cursor(buf, 20)
    assert off == offs[1]
    recs = pages.decode_page(s, buf, off)
    assert np.array_equal(recs["tokens"], toks_b)

    # cursor on an exact page boundary starts at that page
    assert pages.seek_cursor(buf, 32) == offs[2]
    # cursor past the end: nothing to resume
    assert pages.seek_cursor(buf, 48) is None
    # cursor zero: start at the beginning
    assert pages.seek_cursor(buf, 0) == offs[0]
