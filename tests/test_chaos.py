"""End-to-end chaos: the serving stack under a seeded lossy wire.

The acceptance bar for the robustness work: with drop, corrupt and
disconnect faults each injected at >= 5% per frame on BOTH directions,

  * unary ``Infer`` through a ``ResilientChannel`` returns token pages
    bit-identical to the fault-free run, with the handler executing
    exactly once per logical call (idempotency-key dedup);
  * ``InferStream`` delivers the exact fault-free token sequence —
    gap-free and duplicate-free — across however many cursor resumes the
    faults force;
  * no KV blocks leak: after the dust settles the allocator holds its
    full capacity again (prefix cache off, so free == capacity exactly);
  * a client that disconnects mid-``Infer`` without an idempotency key
    has its blocks reclaimed promptly (cancel-on-disconnect);
  * graceful drain finishes in-flight work before shutdown.

Seeds: one fixed seed always runs in CI tier-1; set ``CHAOS_SWEEP=N`` to
add N random seeds (the scheduled chaos-sweep workflow uses 25).  The
failing seed appears in the pytest parameter id — reproduce with
``pytest "tests/test_chaos.py::test_chaos_infer_bit_identical[<seed>]"``.
"""
import os
import queue
import random
import threading
import time

import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import wire
from repro.core.rpc import (Channel, FaultInjectingTransport, FaultSpec,
                            ResilientChannel, RpcError, connected_pair)
from repro.core.retry import RetryPolicy
from repro.serving import Engine, ServeConfig, build_server
from repro.serving.service import (InferChunk, InferenceImpl,
                                   InferenceService, InferRequest,
                                   encode_prompt_page)

FIXED_SEED = 20240808
_sweep = int(os.environ.get("CHAOS_SWEEP", "0") or 0)
if os.environ.get("CHAOS_SEEDS"):           # explicit repro list
    SEEDS = [int(s) for s in os.environ["CHAOS_SEEDS"].split(",")]
else:
    SEEDS = [FIXED_SEED] + [random.SystemRandom().randrange(1 << 31)
                            for _ in range(_sweep)]

#: the acceptance bar: every damaging fault class at >= 5% per frame
CHAOS = FaultSpec(drop=0.05, corrupt=0.05, disconnect=0.05)

#: per-attempt wait is short (the engine is warm after the baseline run);
#: attempts are generous because a 15%-per-frame fault rate can kill
#: several attempts in a row
POLICY = RetryPolicy(attempts=12, base_delay=0.02, max_delay=0.1,
                     jitter=0.25, retry_on=ResilientChannel.RETRYABLE)
ATTEMPT_TIMEOUT = 2.0


@pytest.fixture(scope="module", autouse=True)
def lock_order_canary():
    """Opt-in dynamic lock-order validation (``REPRO_LOCK_ORDER=1``).

    Installs :mod:`repro.analysis.runtime`'s ``OrderedLock`` patch before
    the engine/server fixtures create any locks, so every lock the chaos
    run exercises lands in the global acquisition-order graph.  An ABBA
    ordering raises at the acquisition site *and* is re-asserted here at
    teardown, in case a worker thread swallowed the exception.  The
    nightly chaos sweep runs with this on; plain tier-1 runs skip the
    patch entirely.
    """
    from repro.analysis import runtime
    if not runtime.enabled_by_env():
        yield
        return
    runtime.reset()
    runtime.install()
    try:
        yield
    finally:
        runtime.uninstall()
    assert not runtime.VIOLATIONS, (
        f"lock-order violations during chaos run: {runtime.VIOLATIONS}")


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen2-1.5b"))
    # prefix_cache off so block conservation is exact: free == capacity
    # once no request is resident (cached prefixes intentionally linger)
    engine = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=8,
                                     prefix_cache=False))
    impl = InferenceImpl(engine)
    server = build_server(engine, impl=impl)
    # fault-free baseline (also warms the jit caches so the short chaos
    # attempt timeouts never race a cold compile)
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    prompt = (np.random.default_rng(1234)
              .integers(0, cfg.vocab_size, (1, 8)).astype(np.uint32))
    req = {"page": encode_prompt_page(prompt), "max_new_tokens": 6}
    inf = ch.typed(InferenceService)
    baseline_page = bytes(bytearray(inf.Infer(dict(req))["page"]))
    sid = InferenceService.method("InferStream").id
    raw = wire.encode(InferRequest, req)
    baseline_stream = [
        bytes(bytearray(wire.decode(InferChunk, i.payload)["page"]))
        for i in ch.call(sid, raw, server_stream=True)]
    assert len(baseline_stream) == 6
    yield {"cfg": cfg, "engine": engine, "impl": impl, "server": server,
           "req": req, "raw": raw, "sid": sid,
           "baseline_page": baseline_page,
           "baseline_stream": baseline_stream}
    ch.close()


def _chaos_factory(server, seed):
    """Each dial: fresh in-memory pair, chaos wrappers on BOTH directions,
    seeds derived from (seed, dial index) so runs are reproducible."""
    dials = {"n": 0}

    def dial():
        ct, st = connected_pair()
        k = dials["n"]
        dials["n"] += 1
        server.serve_transport(
            FaultInjectingTransport(st, CHAOS, seed=seed * 1000 + 2 * k + 1),
            blocking=False)
        return FaultInjectingTransport(ct, CHAOS, seed=seed * 1000 + 2 * k)

    return dial


def _free_blocks(impl):
    return impl.batcher.cache.num_free_blocks


def _capacity(impl):
    return impl.batcher.cache.allocator.capacity


def _wait_conserved(impl, timeout=15.0):
    """True once every KV block is back in the pool."""
    end = time.monotonic() + timeout
    while time.monotonic() < end:
        if _free_blocks(impl) == _capacity(impl):
            return True
        time.sleep(0.02)
    return False


@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_infer_bit_identical(setup, seed):
    server, impl = setup["server"], setup["impl"]
    rc = ResilientChannel(_chaos_factory(server, seed), policy=POLICY)
    inf = rc.typed(InferenceService)
    before = impl.batcher.stats["requests"]
    for _ in range(3):
        res = inf.Infer(dict(setup["req"]), timeout=ATTEMPT_TIMEOUT)
        page = bytes(bytearray(res["page"]))
        assert page == setup["baseline_page"], \
            f"seed {seed}: tokens diverged from fault-free baseline"
    # exactly-once: dedup means retries never reach the batcher twice
    assert impl.batcher.stats["requests"] - before == 3, \
        f"seed {seed}: handler executed more than once per logical call"
    rc.close()
    assert _wait_conserved(impl), \
        f"seed {seed}: leaked KV blocks " \
        f"({_free_blocks(impl)}/{_capacity(impl)} free)"


@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_infer_stream_gap_and_duplicate_free(setup, seed):
    server = setup["server"]
    rc = ResilientChannel(_chaos_factory(server, seed + 7), policy=POLICY)
    it = rc.call(setup["sid"], setup["raw"], server_stream=True,
                 timeout=ATTEMPT_TIMEOUT)
    pages, cursors = [], []
    for item in it:
        chunk = wire.decode(InferChunk, item.payload)
        pages.append(bytes(bytearray(chunk["page"])))
        cursors.append(item.cursor)
    assert pages == setup["baseline_stream"], \
        f"seed {seed}: stream diverged (gaps, dups, or wrong tokens)"
    assert cursors == sorted(set(cursors)), \
        f"seed {seed}: cursors not strictly increasing: {cursors}"
    rc.close()
    assert _wait_conserved(setup["impl"]), f"seed {seed}: leaked KV blocks"


def test_unkeyed_disconnect_reclaims_blocks(setup):
    """A plain Channel (no idempotency key) that dies mid-Infer must not
    keep paying for decode: cancel-on-disconnect frees its blocks.

    To beat the race against a warm engine finishing instantly, the
    victim is submitted behind a full batch of filler requests from a
    healthy connection, so it is still pending when its connection dies.
    """
    server, impl = setup["server"], setup["impl"]
    stats = impl.batcher.stats
    cancelled_before = stats["cancelled"]
    requests_before = stats["requests"]
    sid = InferenceService.method("Infer").id
    raw = wire.encode(InferRequest, dict(setup["req"], max_new_tokens=8))

    # healthy connection: enough fillers to occupy every batch slot
    kct, kst = connected_pair()
    server.serve_transport(kst, blocking=False)
    keeper = Channel(kct)
    n_fill = impl.batcher.max_batch
    fills: "queue.Queue" = queue.Queue()
    for _ in range(n_fill):
        threading.Thread(
            target=lambda: fills.put(keeper.call(sid, raw, timeout=30.0)),
            daemon=True).start()
    deadline = time.monotonic() + 10.0
    while stats["requests"] < requests_before + n_fill \
            and time.monotonic() < deadline:
        time.sleep(0.002)

    # doomed connection: victim queues behind the fillers, then vanishes
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    results: "queue.Queue" = queue.Queue()

    def call():
        try:
            results.put(ch.call(sid, raw, timeout=30.0))
        except RpcError as e:
            results.put(e)

    threading.Thread(target=call, daemon=True).start()
    while stats["requests"] < requests_before + n_fill + 1 \
            and time.monotonic() < deadline:
        time.sleep(0.002)
    ct.close()         # the caller vanishes
    st.close()
    for _ in range(n_fill):
        fills.get(timeout=30.0)  # fillers unaffected by the dead peer
    assert _wait_conserved(impl), "disconnected caller's blocks leaked"
    out = results.get(timeout=10.0)
    assert isinstance(out, RpcError)  # the local call observed the loss
    assert stats["cancelled"] > cancelled_before, \
        "dead connection's pending request was never cancelled"
    keeper.close()


def _router_setup(setup, n=2, **cfg_kw):
    """N killable in-process replicas (own batchers, shared engine)
    behind a router server, plus a plain client channel to the router."""
    from repro.serving import InProcessReplica
    from repro.serving.router import RouterConfig, build_router_server
    replicas = [InProcessReplica(setup["engine"], f"rep{i}")
                for i in range(n)]
    cfg_kw.setdefault("health_interval_s", 0)   # tests poll manually
    cfg_kw.setdefault("hedge", False)
    # the 8-token chaos prompt spans one affinity block at block=8, so
    # identical prompts pin to one deterministic victim replica
    cfg_kw.setdefault("affinity_block", 8)
    cfg_kw.setdefault("affinity_prefix", 8)
    server, router = build_router_server(replicas, RouterConfig(**cfg_kw))
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    return replicas, router, Channel(ct)


def test_router_stream_survives_replica_kill(setup):
    """Kill the replica carrying an InferStream mid-flight: the client's
    plain Channel sees the untouched baseline sequence — the router
    resumes on the survivor from its delivered-cursor watermark."""
    replicas, router, ch = _router_setup(setup)
    try:
        pages, killed = [], []
        for item in ch.call(setup["sid"], setup["raw"], server_stream=True,
                            timeout=30.0):
            chunk = wire.decode(InferChunk, item.payload)
            pages.append(bytes(bytearray(chunk["page"])))
            if len(pages) == 2 and not killed:
                for rep, robj in zip(replicas, router.replicas):
                    if robj.inflight:
                        rep.kill()
                        killed.append(rep.name)
        assert killed, "no replica was carrying the stream"
        assert pages == setup["baseline_stream"], \
            "stream diverged across the replica kill (gap, dup, or " \
            "wrong tokens)"
        assert router.stats["stream_failovers"] >= 1
        survivor = next(r for r in replicas if r.alive)
        assert _wait_conserved(survivor.impl), "survivor leaked KV blocks"
    finally:
        ch.close()
        router.close()
        for r in replicas:
            r.kill()


def test_router_infer_exactly_once_across_crash(setup):
    """Crash the replica executing a keyed Infer: the router resubmits
    to the survivor under the same key, the client gets exactly one
    bit-identical result, and a client-keyed retry replays from the
    router's dedup instead of re-executing."""
    from repro.core.rpc import IDEMPOTENCY_KEY
    replicas, router, ch = _router_setup(setup)
    try:
        # affinity makes the victim deterministic: the ring owner of the
        # chaos prompt's first block
        key = router._affinity_key(setup["raw"])
        assert key is not None
        victim_rep = next(router._ring_order(key))
        victim = replicas[router.replicas.index(victim_rep)]
        survivor = next(r for r in replicas if r is not victim)

        results: "queue.Queue" = queue.Queue()

        def call():
            try:
                results.put(ch.call(InferenceService.method("Infer").id,
                                    setup["raw"], timeout=30.0))
            except RpcError as e:
                results.put(e)

        threading.Thread(target=call, daemon=True).start()
        deadline = time.monotonic() + 10.0
        while not victim_rep.inflight and time.monotonic() < deadline:
            time.sleep(0.002)
        assert victim_rep.inflight, "victim never received the call"
        victim.kill()
        out = results.get(timeout=30.0)
        assert not isinstance(out, Exception), out
        res = wire.decode(
            InferenceService.method("Infer").response, bytes(out))
        assert bytes(bytearray(res["page"])) == setup["baseline_page"], \
            "failover produced different tokens"
        assert router.stats["failovers"] >= 1

        # same logical call, client-keyed, sent twice: the router's own
        # dedup replays it, the survivor executes once
        before = survivor.impl.batcher.stats["requests"]
        md = {IDEMPOTENCY_KEY: "chaos-keyed-1"}
        r1 = ch.call(InferenceService.method("Infer").id, setup["raw"],
                     metadata=dict(md), timeout=30.0)
        r2 = ch.call(InferenceService.method("Infer").id, setup["raw"],
                     metadata=dict(md), timeout=30.0)
        assert bytes(r1) == bytes(r2)
        assert survivor.impl.batcher.stats["requests"] - before == 1
        assert _wait_conserved(survivor.impl), "survivor leaked KV blocks"

        # the replica's Stats RPC surfaces the resilience counters
        direct = Channel(survivor.dial())
        names = direct.typed(InferenceService).Stats({})["names"].split("\n")
        for k in ("server_conn_errors", "server_dedup_hits",
                  "server_dedup_evictions", "server_dedup_entries"):
            assert k in names, f"replica Stats missing {k}"
        direct.close()
    finally:
        ch.close()
        router.close()
        for r in replicas:
            r.kill()


# -- replica supervisor (stub processes, zero wall-clock) ---------------------

class _StubProc:
    def __init__(self):
        self.exit = None
        self.terminated = False

    def poll(self):
        return self.exit

    def terminate(self):
        self.terminated = True
        if self.exit is None:
            self.exit = 0

    def wait(self, timeout=None):
        return self.exit


def _stub_supervisor(count=2, **kw):
    from repro.launch.serve import ReplicaSupervisor
    spawned = []

    def spawn(i):
        h = _StubProc()
        spawned.append((i, h))
        return h

    kw.setdefault("policy", RetryPolicy(attempts=3, base_delay=1.0,
                                        multiplier=2.0, max_delay=8.0,
                                        jitter=0.0))
    sleeps = []
    clk = {"t": 0.0}
    kw.setdefault("sleep", sleeps.append)
    kw.setdefault("clock", lambda: clk["t"])
    kw.setdefault("on_event", lambda msg: None)
    sup = ReplicaSupervisor(spawn, count, **kw)
    # seed the slots without starting the monitor thread (tests drive
    # check() directly, deterministically)
    for i in range(count):
        sup.handles[i] = sup._spawn(i)
        sup._started_at[i] = clk["t"]
    return sup, spawned, sleeps, clk


def test_supervisor_restarts_with_capped_backoff():
    sup, spawned, sleeps, clk = _stub_supervisor(count=2)
    n0 = len(spawned)
    sup.handles[0].exit = 1          # replica 0 crashes
    sup.check()
    assert sup.failures == [1, 0] and sup.restarts == 1
    assert len(spawned) == n0 + 1
    assert sleeps[-1] == 1.0         # first restart: base delay
    # crash-loop: delays double, then cap at the policy's attempts index
    for expected in (2.0, 4.0, 4.0, 4.0):
        sup.handles[0].exit = 1
        sup.check()
        assert sleeps[-1] == expected
    assert sup.failures[0] == 5
    # stays up past stable_after_s: the crash history is forgiven
    clk["t"] += sup.stable_after_s + 1.0
    sup.check()
    assert sup.failures[0] == 0
    sup.handles[0].exit = 1          # next crash is cheap again
    sup.check()
    assert sleeps[-1] == 1.0


def test_supervisor_rolling_restart_and_stop():
    sup, spawned, sleeps, clk = _stub_supervisor(count=3)
    old = list(sup.handles)
    sup.rolling_restart(drain_timeout=1.0)
    assert all(h.terminated for h in old)
    assert all(new is not o for new, o in zip(sup.handles, old))
    assert sup.restarts == 3
    # after stop() a crashed replica is NOT respawned
    n = len(spawned)
    sup.stop(timeout=0.1)
    assert all(h.terminated for h in sup.handles)
    sup.handles[0].exit = 1
    sup.check()
    assert len(spawned) == n


def test_health_and_drain_complete_inflight(setup):
    """Drain on a dedicated server sharing the engine: Health answers
    while draining, in-flight Infer completes before shutdown."""
    engine, impl = setup["engine"], setup["impl"]
    server = build_server(engine, impl=impl)   # fresh server, same batcher
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    inf = ch.typed(InferenceService)
    h = inf.Health({"verbose": True})
    assert h["serving"] and not h["draining"]
    assert "names" in h  # verbose gauges present

    # enough concurrent calls that the batcher needs two waves: the
    # server stays busy long enough for drain to be observed mid-flight
    n_calls = impl.batcher.max_batch + 1
    results: "queue.Queue" = queue.Queue()
    for _ in range(n_calls):
        threading.Thread(
            target=lambda: results.put(
                inf.Infer(dict(setup["req"]), timeout=30.0)),
            daemon=True).start()
    deadline = time.monotonic() + 10.0
    while server.inflight < n_calls and time.monotonic() < deadline:
        time.sleep(0.002)
    assert server.inflight == n_calls
    drained: "queue.Queue" = queue.Queue()
    threading.Thread(target=lambda: drained.put(server.drain(timeout=30.0)),
                     daemon=True).start()
    while not server.draining and time.monotonic() < deadline:
        time.sleep(0.001)
    # Health still answers while draining (drain-exempt), reports it
    h2 = inf.Health({})
    assert h2["draining"] and not h2["serving"]
    # new inference is refused while draining
    ct2, st2 = connected_pair()
    server.serve_transport(st2, blocking=False)
    ch2 = Channel(ct2)
    with pytest.raises(RpcError):
        ch2.typed(InferenceService).Infer(dict(setup["req"]), timeout=5.0)
    # every in-flight call completed with the right answer; drain waited
    for _ in range(n_calls):
        res = results.get(timeout=30.0)
        assert bytes(bytearray(res["page"])) == setup["baseline_page"]
    assert drained.get(timeout=30.0) is True
    ch.close()
    ch2.close()
