"""Tests for the repro.analysis static-checker suite and runtime canary.

Covers the framework (noqa, caching, reporters, CLI exit codes), each
checker with seeded-violation / clean / suppressed fixtures — including
the PR 8 ``except RpcError``-before-``TransportError`` router bug as a
regression fixture — the self-cleanliness of the shipped tree, and the
OrderedLock dynamic lock-order validator.
"""
import json
import os
import textwrap
import threading

import pytest

import repro.analysis
from repro.analysis import all_checkers, analyze_source, get_checker
from repro.analysis import runtime
from repro.analysis.__main__ import main as cli_main
from repro.analysis.core import (Cache, Finding, analyze_paths,
                                 iter_python_files, suite_fingerprint)
from repro.analysis.reporters import (render_human, render_json,
                                      render_step_summary)

# repro is a namespace package (no __file__); anchor on the analysis
# subpackage and go one level up to src/repro
REPRO_PKG = os.path.dirname(os.path.dirname(
    os.path.abspath(repro.analysis.__file__)))


def run_check(source, check_id=None):
    """Analyze a dedented snippet; return findings (optionally filtered)."""
    res = analyze_source(textwrap.dedent(source))
    assert res.error is None, res.error
    if check_id is None:
        return res.findings
    return [f for f in res.findings if f.check_id == check_id]


# ---------------------------------------------------------------------------
# framework
# ---------------------------------------------------------------------------

def test_registry_exposes_the_four_checkers():
    ids = [c.id for c in all_checkers()]
    assert ids == ["RPR001", "RPR002", "RPR003", "RPR004"]
    for c in all_checkers():
        assert c.invariant and c.motivation, c.id
        assert get_checker(c.id) is c


def test_finding_render_and_roundtrip():
    f = Finding(path="a.py", line=3, col=4, check_id="RPR001", message="m")
    assert f.render() == "a.py:3:4: RPR001 m"
    assert Finding.from_dict(f.as_dict()) == f


def test_noqa_suppresses_only_named_check_on_that_line():
    bad = """
    try:
        pass
    except Exception:
        pass
    except ValueError:
        pass
    """
    assert run_check(bad, "RPR001")
    suppressed = textwrap.dedent(bad).replace(
        "except Exception:",
        "except Exception:  # repro: noqa(RPR001) deliberate broad-first")
    res = analyze_source(suppressed)
    assert not res.findings
    assert res.suppressed == 1
    wrong_id = textwrap.dedent(bad).replace(
        "except Exception:", "except Exception:  # repro: noqa(RPR004)")
    assert analyze_source(wrong_id).findings


def test_syntax_error_is_reported_not_raised():
    res = analyze_source("def f(:\n")
    assert res.error and "syntax" in res.error
    assert not res.findings


def test_iter_python_files_skips_hidden_and_pycache(tmp_path):
    (tmp_path / "a.py").write_text("x = 1\n")
    (tmp_path / ".hidden").mkdir()
    (tmp_path / ".hidden" / "b.py").write_text("x = 1\n")
    (tmp_path / "__pycache__").mkdir()
    (tmp_path / "__pycache__" / "c.py").write_text("x = 1\n")
    found = list(iter_python_files([str(tmp_path)]))
    assert found == [str(tmp_path / "a.py")]


BAD_STATS = """
class C:
    def __init__(self):
        self.stats = {"hits": 0}

    def poke(self):
        self.stats["misses"] += 1
"""


def test_cache_hit_miss_and_invalidation(tmp_path):
    src = tmp_path / "mod.py"
    src.write_text(textwrap.dedent(BAD_STATS))
    cache = str(tmp_path / "cache.json")

    first = analyze_paths([str(src)], cache_path=cache)
    assert len(first) == 1 and first[0].findings and not first[0].cached

    second = analyze_paths([str(src)], cache_path=cache)
    assert second[0].cached
    assert second[0].findings == first[0].findings

    # content change invalidates the entry
    src.write_text(textwrap.dedent(BAD_STATS).replace(
        '{"hits": 0}', '{"hits": 0, "misses": 0}'))
    third = analyze_paths([str(src)], cache_path=cache)
    assert not third[0].cached and not third[0].findings


def test_cache_ignored_on_fingerprint_mismatch(tmp_path):
    cache_path = str(tmp_path / "cache.json")
    stale = Cache(cache_path, "old-fingerprint")
    stale.put("mod.py", "x = 1\n", [], 0)
    stale.save()
    fresh = Cache(cache_path, suite_fingerprint(all_checkers()))
    assert fresh.get("mod.py", "x = 1\n") is None


def test_corrupt_cache_is_a_cold_start_not_a_crash(tmp_path):
    cache_path = tmp_path / "cache.json"
    cache_path.write_text("{not json")
    src = tmp_path / "mod.py"
    src.write_text("x = 1\n")
    results = analyze_paths([str(src)], cache_path=str(cache_path))
    assert results[0].error is None and not results[0].cached


def test_reporters_render_totals_and_tables():
    res = analyze_source(textwrap.dedent(BAD_STATS), path="mod.py")
    human = render_human([res])
    assert "mod.py:" in human and "1 finding(s)" in human
    blob = json.loads(render_json([res]))
    assert blob["files_checked"] == 1
    assert blob["findings"][0]["check_id"] == "RPR004"
    summary = render_step_summary([res], all_checkers())
    assert "❌" in summary and "RPR004" in summary
    clean = analyze_source("x = 1\n", path="ok.py")
    assert "✅" in render_step_summary([clean], all_checkers())


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exit_codes_and_json(tmp_path, capsys, monkeypatch):
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(BAD_STATS))
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")

    assert cli_main([str(clean), "--no-cache"]) == 0
    assert cli_main([str(bad), "--no-cache"]) == 1
    out = capsys.readouterr().out
    assert "RPR004" in out

    assert cli_main([str(bad), "--no-cache", "--format", "json"]) == 1
    blob = json.loads(capsys.readouterr().out)
    assert blob["findings"]

    # selecting a checker that does not fire on this file passes
    assert cli_main([str(bad), "--no-cache", "--select", "RPR001"]) == 0
    assert cli_main(["--select", "NOPE"]) == 2
    empty = tmp_path / "empty"
    empty.mkdir()
    assert cli_main([str(empty), "--no-cache"]) == 2
    assert cli_main(["--list-checks"]) == 0
    assert "RPR001" in capsys.readouterr().out


def test_cli_appends_github_step_summary(tmp_path, capsys, monkeypatch):
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(BAD_STATS))
    assert cli_main([str(bad), "--no-cache"]) == 1
    capsys.readouterr()
    text = summary.read_text()
    assert "Static analysis" in text and "RPR004" in text


def test_cli_unparseable_file_fails_the_run(tmp_path, capsys):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    assert cli_main([str(bad), "--no-cache"]) == 1
    assert "syntax" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# RPR001 exception-order
# ---------------------------------------------------------------------------

# the PR 8 router bug, verbatim shape: the broad RpcError clause ahead of
# the retryable transport clause made wire failures look like application
# errors, so healthy replicas were drained instead of retried
PR8_ROUTER_FIXTURE = """
def call_replica(replica, request):
    try:
        return replica.invoke(request)
    except RpcError:
        replica.mark_draining()
        raise
    except (TransportError, ClientTimeout):
        replica.breaker.record_failure()
        raise
"""


def test_rpr001_pr8_router_regression():
    findings = run_check(PR8_ROUTER_FIXTURE, "RPR001")
    assert len(findings) == 1
    f = findings[0]
    # anchored at the broad clause (where a deliberate noqa would go)
    assert f.line == 5
    assert "RpcError" in f.message
    assert "TransportError" in f.message
    assert "unreachable" in f.message


def test_rpr001_pr8_fixture_fails_via_cli(tmp_path, capsys, monkeypatch):
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    fixture = tmp_path / "router_bug.py"
    fixture.write_text(textwrap.dedent(PR8_ROUTER_FIXTURE))
    assert cli_main([str(fixture), "--no-cache"]) == 1
    assert "RPR001" in capsys.readouterr().out


def test_rpr001_narrowest_first_is_clean():
    assert not run_check("""
    def f(replica):
        try:
            replica.invoke()
        except (TransportError, ClientTimeout):
            pass
        except RpcError:
            pass
        except Exception:
            pass
    """, "RPR001")


def test_rpr001_builtin_hierarchy():
    findings = run_check("""
    try:
        pass
    except OSError:
        pass
    except ConnectionError:
        pass
    """, "RPR001")
    assert len(findings) == 1 and "ConnectionError" in findings[0].message


def test_rpr001_duplicate_class():
    findings = run_check("""
    try:
        pass
    except ValueError:
        pass
    except ValueError:
        pass
    """, "RPR001")
    assert len(findings) == 1 and "duplicates" in findings[0].message


def test_rpr001_local_class_hierarchy():
    findings = run_check("""
    class Base(Exception):
        pass

    class Leaf(Base):
        pass

    try:
        pass
    except Base:
        pass
    except Leaf:
        pass
    """, "RPR001")
    assert len(findings) == 1 and "Leaf" in findings[0].message


def test_rpr001_retryable_alias_resolves():
    findings = run_check("""
    try:
        pass
    except RpcError:
        pass
    except RETRYABLE:
        pass
    """, "RPR001")
    assert len(findings) == 1


def test_rpr001_local_tuple_alias():
    findings = run_check("""
    FATAL = (ValueError, KeyError)
    try:
        pass
    except Exception:
        pass
    except FATAL:
        pass
    """, "RPR001")
    assert len(findings) == 1


def test_rpr001_opaque_names_are_conservative():
    assert not run_check("""
    try:
        pass
    except some_module.DynamicError:
        pass
    except ValueError:
        pass
    """, "RPR001")


def test_rpr001_bare_except_catches_everything():
    findings = run_check("""
    try:
        pass
    except:
        pass
    except ValueError:
        pass
    """, "RPR001")
    assert len(findings) == 1


# ---------------------------------------------------------------------------
# RPR002 lock-discipline
# ---------------------------------------------------------------------------

# the PR 8 replica bug this suite's fix addressed: start() republished
# impl/server/_dead without the lock that kill()/dial() hold, so a dial
# racing a restart could observe _dead flipped with stale impl/server
REPLICA_PREFIX_FIXTURE = """
import threading

class InProcessReplica:
    def __init__(self):
        self._lock = threading.Lock()
        self._dead = True
        self.impl = None
        self.server = None

    def start(self):
        self.impl = object()
        self.server = object()
        self._dead = False

    def kill(self):
        with self._lock:
            self._dead = True
            self.impl = None
            self.server = None
"""


def test_rpr002_replica_unlocked_publish_regression():
    findings = run_check(REPLICA_PREFIX_FIXTURE, "RPR002")
    flagged = {f.line for f in findings}
    # all three start() writes are outside the lock kill() establishes
    assert flagged == {12, 13, 14}
    assert all("without holding" in f.message for f in findings)


def test_rpr002_locked_everywhere_is_clean():
    assert not run_check("""
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0

        def bump(self):
            with self._lock:
                self.n += 1

        def reset(self):
            with self._lock:
                self.n = 0
    """, "RPR002")


def test_rpr002_explicit_annotation_creates_guard():
    findings = run_check("""
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0   # guarded by _lock

        def bump(self):
            self.n += 1
    """, "RPR002")
    assert len(findings) == 1 and findings[0].line == 10


def test_rpr002_exemptions():
    assert not run_check("""
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0

        def bump(self):
            with self._lock:
                self.n += 1

        def _bump_locked(self):
            self.n += 1

        def merge(self):
            '''Caller holds self._lock.'''
            self.n = 0
    """, "RPR002")


def test_rpr002_closure_needs_its_own_lock():
    findings = run_check("""
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0

        def bump(self):
            with self._lock:
                self.n += 1

        def spawn(self):
            with self._lock:
                def worker():
                    self.n = 5
                return worker
    """, "RPR002")
    # the with wraps the def, not the call: the closure body runs later,
    # lockless, on another thread
    assert len(findings) == 1 and findings[0].line == 16


def test_rpr002_condition_counts_as_lock():
    findings = run_check("""
    import threading

    class C:
        def __init__(self):
            self._cond = threading.Condition()
            self.q = []

        def put(self, x):
            with self._cond:
                self.q.append(x)
                self.q = self.q

        def clear(self):
            self.q = []
    """, "RPR002")
    assert len(findings) == 1 and "self._cond" in findings[0].message


def test_rpr002_noqa_single_writer():
    res = analyze_source(textwrap.dedent("""
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0

        def bump(self):
            with self._lock:
                self.n += 1

        def owner_thread_only(self):
            self.n = 0  # repro: noqa(RPR002) single writer thread
    """))
    assert not [f for f in res.findings if f.check_id == "RPR002"]
    assert res.suppressed == 1


# ---------------------------------------------------------------------------
# RPR003 jit-purity
# ---------------------------------------------------------------------------

def test_rpr003_traced_branch_in_jitted_fn():
    findings = run_check("""
    import jax

    @jax.jit
    def step(x):
        if x > 0:
            return x
        return -x
    """, "RPR003")
    assert len(findings) == 1 and "if" in findings[0].message


def test_rpr003_static_argnames_branch_is_clean():
    assert not run_check("""
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("n",))
    def step(x, n):
        if n > 1:
            return x * n
        return x
    """, "RPR003")


def test_rpr003_shape_len_and_is_none_are_static():
    assert not run_check("""
    import jax

    @jax.jit
    def step(x, mask):
        if x.shape[0] > 1:
            x = x + 1
        if len(x.shape) == 2:
            x = x + 2
        if mask is not None:
            x = x + 3
        return x
    """, "RPR003")


def test_rpr003_host_syncs_and_print():
    findings = run_check("""
    import jax
    import numpy as np

    @jax.jit
    def step(x):
        print("tracing", x)
        v = float(x)
        w = x.sum().item()
        h = np.asarray(x)
        return v + w + h
    """, "RPR003")
    msgs = " | ".join(f.message for f in findings)
    assert "print" in msgs
    assert "float" in msgs
    assert ".item()" in msgs
    assert "np.asarray" in msgs
    assert len(findings) == 4


def test_rpr003_marker_comment_zone():
    findings = run_check("""
    def step(params, x):  # repro: jit-pure
        while x > 0:
            x = x - 1
        return x
    """, "RPR003")
    assert len(findings) == 1 and "while" in findings[0].message


def test_rpr003_marker_statics():
    assert not run_check("""
    def step(x, n):  # repro: jit-pure(static=n)
        if n > 1:
            return x * n
        return x
    """, "RPR003")


def test_rpr003_pallas_partial_alias_kernel():
    findings = run_check("""
    import functools
    import jax.experimental.pallas as pl

    def _kernel(x_ref, o_ref, *, scale):
        print("inside kernel")
        o_ref[...] = x_ref[...] * scale

    def launch(x, scale):
        kernel = functools.partial(_kernel, scale=scale)
        return pl.pallas_call(kernel, out_shape=x)(x)
    """, "RPR003")
    assert len(findings) == 1 and "print" in findings[0].message


def test_rpr003_partial_bound_kwargs_are_static():
    assert not run_check("""
    import functools
    import jax.experimental.pallas as pl

    def _kernel(x_ref, o_ref, *, n):
        if n > 1:
            o_ref[...] = x_ref[...]

    def launch(x):
        kernel = functools.partial(_kernel, n=4)
        return pl.pallas_call(kernel, out_shape=x)(x)
    """, "RPR003")


def test_rpr003_vararg_unrolling_is_clean():
    # `*o_refs` is a Python tuple of refs: static-length unrolling is
    # the normal Pallas multi-output idiom, not a traced loop
    assert not run_check("""
    import jax.experimental.pallas as pl

    def _kernel(x_ref, *o_refs):
        for i, o_ref in enumerate(o_refs):
            o_ref[...] = x_ref[...] + i

    def launch(x, outs):
        return pl.pallas_call(_kernel, out_shape=outs)(x)
    """, "RPR003")


def test_rpr003_noqa_deliberate_sync():
    res = analyze_source(textwrap.dedent("""
    import jax

    @jax.jit
    def step(x):
        v = float(x)  # repro: noqa(RPR003) debug-only path
        return v
    """))
    assert not [f for f in res.findings if f.check_id == "RPR003"]
    assert res.suppressed == 1


def test_rpr003_undecorated_fn_is_not_a_zone():
    assert not run_check("""
    def host_side(x):
        if x > 0:
            print(x)
        return float(x)
    """, "RPR003")


# ---------------------------------------------------------------------------
# RPR004 stats-keys
# ---------------------------------------------------------------------------

def test_rpr004_missing_key_read_and_write():
    findings = run_check("""
    class C:
        def __init__(self):
            self.stats = {"hits": 0}

        def poke(self):
            self.stats["misses"] += 1
            return self.stats["evictions"]
    """, "RPR004")
    assert {f.message.split("'")[1] for f in findings} == \
        {"misses", "evictions"}
    assert all("line 4" in f.message for f in findings)


def test_rpr004_initialized_keys_are_clean():
    assert not run_check("""
    class C:
        def __init__(self):
            self.stats = {"hits": 0, "misses": 0}

        def poke(self):
            self.stats["hits"] += 1
            self.stats["misses"] = 0
    """, "RPR004")


def test_rpr004_non_literal_dict_skips_class():
    assert not run_check("""
    class C:
        def __init__(self, base):
            self.stats = dict(base)

        def poke(self):
            self.stats["anything"] += 1
    """, "RPR004")


def test_rpr004_multiple_assigns_union():
    assert not run_check("""
    class C:
        def __init__(self):
            self.stats = {"hits": 0}

        def reset(self):
            self.stats = {"hits": 0, "misses": 0}

        def poke(self):
            self.stats["misses"] += 1
    """, "RPR004")


def test_rpr004_nested_class_isolated():
    findings = run_check("""
    class Outer:
        def __init__(self):
            self.stats = {"outer": 0}

        class Inner:
            def __init__(self):
                self.stats = {"inner": 0}

            def poke(self):
                self.stats["inner"] += 1

        def poke(self):
            self.stats["outer"] += 1
    """, "RPR004")
    assert not findings


def test_rpr004_dynamic_keys_out_of_scope():
    assert not run_check("""
    class C:
        def __init__(self):
            self.stats = {"hits": 0}

        def poke(self, key):
            self.stats[key] += 1
    """, "RPR004")


# ---------------------------------------------------------------------------
# the shipped tree is clean
# ---------------------------------------------------------------------------

def test_src_tree_is_clean():
    """`python -m repro.analysis src` must exit 0 on the shipped tree."""
    results = analyze_paths([REPRO_PKG], cache_path=None)
    assert results, "no files found under the repro package"
    problems = [f.render() for r in results for f in r.findings]
    problems += [f"{r.path}: {r.error}" for r in results if r.error]
    assert not problems, "\n".join(problems)


# ---------------------------------------------------------------------------
# runtime lock-order canary
# ---------------------------------------------------------------------------

@pytest.fixture()
def clean_graph():
    runtime.reset()
    yield
    runtime.reset()
    runtime.uninstall()


def test_ordered_lock_consistent_order_ok(clean_graph):
    a = runtime.OrderedLock("A")
    b = runtime.OrderedLock("B")
    for _ in range(3):
        with a:
            with b:
                pass
    assert not runtime.VIOLATIONS


def test_ordered_lock_abba_detected(clean_graph):
    a = runtime.OrderedLock("A")
    b = runtime.OrderedLock("B")
    with a:
        with b:
            pass
    with pytest.raises(runtime.LockOrderViolation):
        with b:
            with a:
                pass
    assert runtime.VIOLATIONS
    # the violating acquire released its inner lock on the way out
    assert not a.locked() and not b.locked()


def test_ordered_lock_transitive_cycle(clean_graph):
    a = runtime.OrderedLock("A")
    b = runtime.OrderedLock("B")
    c = runtime.OrderedLock("C")
    with a:
        with b:
            pass
    with b:
        with c:
            pass
    with pytest.raises(runtime.LockOrderViolation):
        with c:
            with a:
                pass


def test_ordered_lock_sequential_use_is_not_nesting(clean_graph):
    a = runtime.OrderedLock("A")
    b = runtime.OrderedLock("B")
    with a:
        pass
    with b:
        pass
    with b:
        pass
    with a:
        pass
    assert not runtime.VIOLATIONS


def test_ordered_lock_condition_compatible(clean_graph):
    cond = threading.Condition(runtime.OrderedLock("cond"))
    got = []

    def worker():
        with cond:
            got.append(1)
            cond.notify()

    with cond:
        t = threading.Thread(target=worker)
        t.start()
        assert cond.wait_for(lambda: got, timeout=5.0)
    t.join()


def test_install_patches_repro_callers_only(clean_graph):
    runtime.install()
    try:
        # a lock created from test code stays a plain lock
        plain = threading.Lock()
        assert not isinstance(plain, runtime.OrderedLock)
        # a lock created from a repro-package source file becomes ordered
        fake = os.path.join(REPRO_PKG, "serving", "fake_module.py")
        ns = {}
        exec(compile("import threading\nlk = threading.Lock()",
                     fake, "exec"), ns)
        assert isinstance(ns["lk"], runtime.OrderedLock)
        assert ns["lk"].name.endswith("fake_module.py:2")
    finally:
        runtime.uninstall()
    assert threading.Lock is runtime._real_lock


def test_install_is_idempotent(clean_graph):
    runtime.install()
    runtime.install()
    runtime.uninstall()
    assert threading.Lock is runtime._real_lock
    runtime.uninstall()  # second uninstall is a no-op
    assert threading.Lock is runtime._real_lock


def test_enabled_by_env(monkeypatch):
    monkeypatch.delenv(runtime.ENV_VAR, raising=False)
    assert not runtime.enabled_by_env()
    monkeypatch.setenv(runtime.ENV_VAR, "1")
    assert runtime.enabled_by_env()
