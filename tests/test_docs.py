"""Doc-drift gates: the docs surface cannot silently rot.

docs/TUNING.md advertises itself as a complete reference of every
``ServeConfig`` field and every ``launch/serve.py`` flag.  These tests
make that claim structural: they introspect the dataclass and the
argparse parser (``build_parser`` exists precisely so the flag surface
is buildable without side effects) and fail the moment a new knob ships
undocumented.
"""
import dataclasses
import pathlib

from repro.analysis import all_checkers
from repro.launch.serve import build_parser
from repro.serving import ServeConfig

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _tuning_text() -> str:
    return (ROOT / "docs" / "TUNING.md").read_text()


def test_every_serve_config_field_documented():
    text = _tuning_text()
    missing = [f.name for f in dataclasses.fields(ServeConfig)
               if f"`{f.name}`" not in text]
    assert not missing, (
        f"ServeConfig fields missing from docs/TUNING.md: {missing} "
        f"(document each as a backticked `field_name` row)")


def test_every_serve_flag_documented():
    text = _tuning_text()
    missing = []
    for action in build_parser()._actions:
        for opt in action.option_strings:
            if opt in ("-h", "--help"):
                continue
            if opt not in text:
                missing.append(opt)
    assert not missing, (
        f"serve.py flags missing from docs/TUNING.md: {missing} "
        f"(BooleanOptionalAction flags need BOTH the --x and --no-x "
        f"spellings mentioned)")


def test_readme_links_both_docs():
    text = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in text
    assert "docs/TUNING.md" in text


def test_every_checker_documented_in_architecture():
    """A registered static check must appear in ARCHITECTURE.md's table.

    Introspects ``repro.analysis.all_checkers()`` so adding RPR005
    without documenting its invariant and motivation fails here.
    """
    text = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    assert "## Static analysis" in text
    missing = [c.id for c in all_checkers() if f"`{c.id}`" not in text]
    assert not missing, (
        f"checkers missing from docs/ARCHITECTURE.md's Static analysis "
        f"table: {missing} (add a row: id, invariant, motivating bug)")
    # the suppression syntax must be documented alongside the checks
    assert "noqa(CHECK-ID)" in text


def test_architecture_covers_the_lifecycle_and_ownership():
    text = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    for state in ("QUEUED", "PREFILLING", "DECODING", "PREEMPTED",
                  "FINISHED", "SHED"):
        assert state in text, f"lifecycle state {state} undocumented"
    for word in ("swap_out", "swap_in", "refcount", "copy-on-write",
                 "null block"):
        assert word in text, f"block-ownership concept {word!r} missing"
