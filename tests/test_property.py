"""Property-based tests (hypothesis): system invariants.

  * encode/decode roundtrip identity for randomly generated (schema, value)
    pairs across the reference codec AND the plan-compiled fast decoder
  * batch decode == N single decodes (fixed-layout structs)
  * varint baseline roundtrip (the comparison must itself be correct)
  * expected-varint-size model (Eq. 1) matches Monte Carlo
  * frame layer roundtrip incl. cursor trailer under arbitrary chunking
  * batch dependency layering: schedule correctness for arbitrary DAGs
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fastwire, types as T, varint, wire
from repro.core.rpc.batch import build_layers
from repro.core.rpc.framing import Flags, Frame, FrameReader, encode_frame

# --------------------------------------------------------------------------
# schema/value strategies
# --------------------------------------------------------------------------

_SCALARS = [
    (T.BOOL, st.booleans()),
    (T.UINT8, st.integers(0, 255)),
    (T.INT16, st.integers(-2**15, 2**15 - 1)),
    (T.UINT32, st.integers(0, 2**32 - 1)),
    (T.INT64, st.integers(-2**63, 2**63 - 1)),
    (T.FLOAT32, st.floats(width=32, allow_nan=False)),
    (T.FLOAT64, st.floats(allow_nan=False)),
    (T.UINT128, st.integers(0, 2**128 - 1)),
    (T.STRING, st.text(max_size=40)),
]


def scalar_pairs():
    return st.sampled_from(_SCALARS)


@st.composite
def struct_and_value(draw, max_fields=5):
    n = draw(st.integers(1, max_fields))
    fields, value = [], {}
    for i in range(n):
        ftype, strat = draw(scalar_pairs())
        if draw(st.booleans()):
            ftype_inner, strat_inner = ftype, strat
            ftype = T.Array(ftype_inner)
            strat = st.lists(strat_inner, max_size=8)
        fields.append(T.Field(f"f{i}", ftype))
        value[f"f{i}"] = draw(strat)
    return T.Struct("S", fields), value


def _norm(v):
    """Normalize decoded values for comparison (numpy arrays -> lists)."""
    if isinstance(v, np.ndarray):
        return [_norm(x) for x in v.tolist()]
    if isinstance(v, dict):
        return {k: _norm(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_norm(x) for x in v]
    if isinstance(v, (np.bool_, bool)):
        return bool(v)
    if isinstance(v, (np.integer, int)):
        return int(v)
    if isinstance(v, (np.floating, float)):
        return float(v)
    return v


@settings(max_examples=150, deadline=None)
@given(struct_and_value())
def test_roundtrip_reference_codec(sv):
    s, v = sv
    buf = wire.encode(s, v)
    out = wire.decode(s, buf)
    assert _norm(out) == _norm(v)


@settings(max_examples=150, deadline=None)
@given(struct_and_value())
def test_fast_decoder_matches_reference(sv):
    s, v = sv
    buf = wire.encode(s, v)
    ref = wire.decode(s, buf)
    fast = fastwire.FastStructDecoder(s).decode_canonical(buf)
    assert _norm(fast) == _norm(ref)
    # the raw fast path must agree on plain numeric fields
    raw = fastwire.FastStructDecoder(s).decode(buf)
    if isinstance(raw, np.void):
        for f in s.fields:
            if isinstance(f.type, T.Prim) and f.type.np_dtype is not None \
                    and f.type.name not in ("bfloat16",):
                assert _norm(raw[f.name]) == _norm(ref[f.name])


@settings(max_examples=100, deadline=None)
@given(struct_and_value())
def test_varint_baseline_roundtrip(sv):
    s, v = sv
    buf = varint.encode(s, v)
    out = varint.decode(s, buf)
    # varint codec degrades float32 via double-encode? no — exact fixed32.
    assert _norm(out) == _norm(v)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**35))
def test_expected_varint_size_model(n_max):
    """Eq. 1 against direct computation on a sample."""
    e = varint.expected_varint_bytes_uniform(n_max)
    assert 1.0 <= e <= 5.0
    # exact check on small ranges
    if n_max <= 4096:
        exact = sum(varint.uvarint_size(v) for v in range(n_max + 1)) \
            / (n_max + 1)
        assert abs(e - exact) < 1e-9


@settings(max_examples=100, deadline=None)
@given(st.integers(-2**31, 2**31 - 1))
def test_varint_negative_int32_is_10_bytes(v):
    """§2.1.3: every negative int32 costs 10 varint bytes (tag adds 1)."""
    b = varint.encode(T.INT32, v)
    if v < 0:
        assert len(b) == 11
    assert varint.decode(T.INT32, b) == v


@settings(max_examples=100, deadline=None)
@given(st.binary(max_size=200),
       st.integers(0, 2**32 - 1),
       st.sampled_from([0, Flags.END_STREAM, Flags.ERROR,
                        Flags.END_STREAM | Flags.ERROR]),
       st.one_of(st.none(), st.integers(0, 2**64 - 1)),
       st.integers(1, 7))
def test_frame_roundtrip_any_chunking(payload, sid, flags, cursor, chunk):
    f = Frame(sid, payload, flags, cursor)
    raw = encode_frame(f)
    reader = FrameReader()
    frames = []
    for i in range(0, len(raw), chunk):
        frames.extend(reader.feed(raw[i:i + chunk]))
    assert len(frames) == 1
    g = frames[0]
    assert g.stream_id == sid and g.payload == payload
    assert g.cursor == cursor
    assert g.flags == flags


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(-1, 20), min_size=1, max_size=24))
def test_batch_layers_schedule_invariants(raw_deps):
    """For any input_from graph: either rejected, or layers are a valid
    topological schedule with every dependency in an earlier layer."""
    calls = [{"call_id": i, "method_id": 1,
              "input_from": (d if d < i else -1)}
             for i, d in enumerate(raw_deps)]
    layers = build_layers(calls)
    seen = {}
    for li, layer in enumerate(layers):
        for idx in layer:
            seen[idx] = li
    assert sorted(seen) == list(range(len(calls)))
    for i, c in enumerate(calls):
        d = c["input_from"]
        if d >= 0:
            assert seen[d] < seen[i]


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16), st.integers(0, 3))
def test_page_roundtrip_and_cursor(n_records, dim, pad_seed):
    from repro.core import pages
    s = T.Struct("R", [T.Field("id", T.UINT64),
                       T.Field("vec", T.FixedArray(T.FLOAT32, dim))])
    dt = fastwire.static_dtype(s)
    recs = np.zeros(n_records, dtype=dt)
    recs["id"] = np.arange(n_records)
    recs["vec"] = np.arange(n_records * dim).reshape(n_records, dim)
    page = pages.write_page("R", recs, first_record=7)
    assert len(page) % pages.PAGE_ALIGN == 0
    out = pages.decode_page(s, page)
    assert (out["id"] == recs["id"]).all()
    assert (out["vec"] == recs["vec"]).all()
    # cursor seek
    assert pages.seek_cursor(page, 7) == 0
    assert pages.seek_cursor(page, 7 + n_records - 1) == 0
    assert pages.seek_cursor(page, 7 + n_records) is None


@settings(max_examples=30, deadline=None)
@given(st.binary(min_size=1, max_size=64))
def test_page_crc_detects_corruption(noise):
    from repro.core import pages
    s = T.Struct("R", [T.Field("id", T.UINT64)])
    recs = np.zeros(8, dtype=fastwire.static_dtype(s))
    page = bytearray(pages.write_page("R", recs))
    pos = pages.HEADER_SIZE + (noise[0] % 64)
    old = page[pos]
    page[pos] = old ^ 0xFF
    with pytest.raises(pages.PageError):
        pages.read_payload(bytes(page), verify=True)
