"""Wire-format unit tests: the paper's §3 worked examples, byte-for-byte."""
import uuid

import numpy as np
import pytest

from repro.core import types as T, wire


def test_point_struct_bytes():
    Point = T.Struct("Point", [T.Field("x", T.FLOAT32),
                               T.Field("y", T.FLOAT32)])
    b = wire.encode(Point, {"x": 1.0, "y": 2.0})
    assert b == bytes.fromhex("0000803f00000040")  # §3.8
    assert wire.decode(Point, b) == {"x": 1.0, "y": 2.0}


def test_empty_struct_is_zero_bytes():
    Empty = T.Struct("Empty", [])
    assert wire.encode(Empty, {}) == b""


def test_string_hello():
    b = wire.encode(T.STRING, "hello")
    assert b == bytes.fromhex("0500000068656c6c6f00")  # §3.5
    assert wire.decode(T.STRING, b) == "hello"


def test_string_nul_terminator_checked():
    b = bytearray(wire.encode(T.STRING, "hi"))
    b[-1] = 1
    with pytest.raises(T.DecodeError):
        wire.decode(T.STRING, bytes(b))


def test_map_example():
    m = T.MapT(T.UINT8, T.INT32)
    b = wire.encode(m, {1: 100, 2: 200})
    assert b == bytes.fromhex("020000000164000000" "02c8000000")  # §3.7
    assert wire.decode(m, b) == {1: 100, 2: 200}


def test_map_rejects_float_keys():
    with pytest.raises(T.SchemaError):
        T.MapT(T.FLOAT32, T.INT32)


def test_union_circle():
    Shape = T.Union("Shape", [
        T.Branch("Circle", 1,
                 T.Struct("Circle", [T.Field("radius", T.FLOAT32)]))])
    b = wire.encode(Shape, ("Circle", {"radius": 5.0}))
    assert b == bytes.fromhex("05000000" "01" "0000a040")  # §3.10
    v = wire.decode(Shape, b)
    assert v.name == "Circle" and v.discriminator == 1
    assert v.value == {"radius": 5.0}


def test_union_unknown_discriminator():
    Shape = T.Union("Shape", [
        T.Branch("Circle", 1,
                 T.Struct("C", [T.Field("radius", T.FLOAT32)]))])
    bad = bytes.fromhex("05000000" "07" "0000a040")
    with pytest.raises(T.DecodeError):
        wire.decode(Shape, bad)


def test_location_message_27_bytes():
    """§3.11 complete example, including the 27-byte total."""
    Coord = T.Struct("Coord", [T.Field("x", T.FLOAT32),
                               T.Field("y", T.FLOAT32)])
    Location = T.Message("Location", [
        T.Field("name", T.STRING, tag=1),
        T.Field("pos", Coord, tag=2),
        T.Field("alt", T.FLOAT32, tag=3)])
    v = {"name": "HQ", "pos": {"x": 1.0, "y": 2.0}, "alt": 100.0}
    b = wire.encode(Location, v)
    assert len(b) == 27
    expect = bytes.fromhex("17000000" "01" "02000000" "485100" "02"
                           "0000803f" "00000040" "03" "0000c842" "00")
    assert b == expect
    assert wire.decode(Location, b) == v


def test_message_absent_fields_not_encoded():
    M = T.Message("M", [T.Field("a", T.INT32, tag=1),
                        T.Field("b", T.STRING, tag=2)])
    b = wire.encode(M, {"a": 7})
    v = wire.decode(M, b)
    assert v == {"a": 7}
    assert "b" not in v  # "not set" distinct from "set to default" (§2.2)


def test_timestamp_wire():
    ts = T.Timestamp(1000, 999999488, 32400000)
    b = wire.encode(T.TIMESTAMP, ts)
    # paper §3.3.1 labels ns=999999488; its printed hex shows 1e9 which is
    # internally inconsistent — we encode the stated VALUE
    assert b == bytes.fromhex("e803000000000000" "00c89a3b" "8062ee01")
    assert wire.decode(T.TIMESTAMP, b) == ts


def test_duration_wire():
    d = T.Duration(60, 0)
    b = wire.encode(T.DURATION, d)
    assert b == bytes.fromhex("3c00000000000000" "00000000")  # §3.3.2
    assert wire.decode(T.DURATION, b) == d


def test_negative_duration_sign_rule():
    with pytest.raises(ValueError):
        T.Duration(-1, 5)  # both fields must share sign (§3.3.2)
    d = T.Duration(-1, -500)
    assert wire.decode(T.DURATION, wire.encode(T.DURATION, d)) == d


def test_uuid_canonical_bytes():
    u = uuid.UUID("550e8400-e29b-41d4-a716-446655440000")
    b = wire.encode(T.UUID, u)
    assert b == bytes.fromhex("550e8400e29b41d4a716446655440000")  # §3.4
    assert wire.decode(T.UUID, b) == u


def test_int128_low_bytes_first():
    v = 2 ** 64 + 5
    b = wire.encode(T.INT128, v)
    assert b[:8] == (5).to_bytes(8, "little")   # low 8 bytes first (§3.2)
    assert b[8:] == (1).to_bytes(8, "little")
    assert wire.decode(T.INT128, b) == v


def test_bfloat16_array_roundtrip():
    arr = np.array([1.0, 2.0, 3.0, 4.0], dtype=np.float32)
    b = wire.encode(T.Array(T.BFLOAT16), arr)
    assert b == bytes.fromhex("04000000" "803f" "0040" "4040" "8040")
    assert np.allclose(wire.decode(T.Array(T.BFLOAT16), b), arr)


def test_fixed_array_no_prefix():
    fa = T.FixedArray(T.UINT16, 3)
    b = wire.encode(fa, [1, 2, 3])
    assert len(b) == 6  # no count prefix (§3.6)
    with pytest.raises(T.EncodeError):
        wire.encode(fa, [1, 2])


def test_fixed_array_max_size():
    with pytest.raises(T.SchemaError):
        T.FixedArray(T.BYTE, 65536)


def test_decode_bounds_checked():
    Point = T.Struct("P", [T.Field("x", T.FLOAT64)])
    with pytest.raises(T.DecodeError):
        wire.decode(Point, b"\x00\x00")


def test_nested_struct_inline_zero_overhead():
    Inner = T.Struct("I", [T.Field("a", T.UINT32)])
    Outer = T.Struct("O", [T.Field("i", Inner), T.Field("b", T.UINT32)])
    b = wire.encode(Outer, {"i": {"a": 1}, "b": 2})
    assert len(b) == 8  # §3.8: no additional overhead


def test_enum_default_zero_required():
    with pytest.raises(T.SchemaError):
        T.Enum("E", {"A": 1, "B": 2})
    e = T.Enum("E", {"Z": 0, "A": 1}, base=T.UINT8)
    assert wire.encode(e, 1) == b"\x01"


def test_message_tag_range():
    with pytest.raises(T.SchemaError):
        T.Message("M", [T.Field("a", T.INT32, tag=256)])
    with pytest.raises(T.SchemaError):
        T.Message("M", [T.Field("a", T.INT32, tag=0)])


# -- vectorized packed-varint baseline (core/varint.py) ------------------------

def test_packed_uvarint_vectorized_byte_exact():
    """read_packed_uvarints == looping read_uvarint, including the >64-bit
    fallback corner and both error cases."""
    from repro.core import varint

    rng = np.random.default_rng(0)
    vals = [int(v) for v in rng.integers(0, 2**63, 64, dtype=np.int64)]
    vals += [0, 1, 127, 128, 2**32, (-1) & 0xFFFFFFFFFFFFFFFF]
    vals += [2**70 - 1]          # >64-bit: exercises the scalar fallback
    buf = bytearray()
    for v in vals:
        varint.write_uvarint(buf, v)
    slow, pos = [], 0
    while pos < len(buf):
        v, pos = varint.read_uvarint(bytes(buf), pos)
        slow.append(v)
    assert varint.read_packed_uvarints(bytes(buf)) == slow
    assert slow[-1] == 2**70 - 1  # Python-int exactness survives fallback
    with pytest.raises(T.DecodeError):
        varint.read_packed_uvarints(b"\x80")            # overruns buffer
    with pytest.raises(T.DecodeError):
        varint.read_packed_uvarints(b"\x80" * 11 + b"\x01")  # too long
    assert varint.read_packed_uvarints(b"") == []


def test_packed_varint_array_field_decodes():
    """The packed repeated-scalar path (the vectorized loop's only caller)
    stays byte-exact through the full codec."""
    from repro.core import varint

    s = T.Struct("P", [T.Field("xs", T.Array(T.INT64))])
    xs = [0, -1, 2**62, -2**62, 5, -5]
    enc = varint.encode(s, {"xs": xs})
    assert varint.decode(s, enc)["xs"] == xs
