"""The swap/preemption tier: KV swap-to-host, SLO-aware scheduling.

Three layers of invariants:

  * ``PagedKVCache`` swap primitives — a swap round-trip is
    content-identical (into whatever physical blocks are free at resume
    time), refcount-aware (a block shared with another request or the
    prefix index is never yanked out from under it), an absent block
    never satisfies a prefix match, and no resources leak in either
    direction (shed-while-swapped reclaims the host image too).
  * ``PagedBatcher`` scheduling — preempt/resume is token-identical to
    an uncontended run, victims are chosen lowest-priority-first /
    most-blocks-first and swapped whole, and a paged-out request whose
    deadline expires is shed with everything reclaimed.
  * The SLO controller — halves/doubles ``max_step_tokens`` toward the
    more-violated of TTFT/TPOT, clamped, window-reset after each move.

Plus the stats-presence regression: every counter key exists from
construction, so dashboards and tests can rely on presence rather than
first increment.
"""
import threading
import time

import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.serving import (CacheOOM, ContinuousBatcher, Engine,
                           PagedBatcher, PagedKVCache, ServeConfig,
                           ShedError)

# ---------------------------------------------------------------------------
# PagedKVCache swap primitives (no engine, tiny geometry)
# ---------------------------------------------------------------------------


def _cache(**kw):
    kw.setdefault("prefix_cache", True)
    return PagedKVCache(num_layers=2, num_kv_heads=1, head_dim=16,
                        cache_len=64, block_size=16, num_blocks=9,
                        max_concurrent=4, **kw)


def _fill_blocks(cache, blocks, seed):
    """Stamp random content into ``blocks``; returns {block: (k, v)}."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    k = np.array(cache.pool["k"])
    v = np.array(cache.pool["v"])
    content = {}
    for b in blocks:
        kb = rng.standard_normal(k[:, b].shape).astype(k.dtype)
        vb = rng.standard_normal(v[:, b].shape).astype(v.dtype)
        k[:, b] = kb
        v[:, b] = vb
        content[b] = (kb, vb)
    cache.pool = {"k": jnp.asarray(k), "v": jnp.asarray(v)}
    return content


def test_swap_roundtrip_restores_content_into_fresh_blocks():
    cache = _cache()
    cache.allocate("A", 40)                       # 3 blocks
    old = list(cache.allocator.blocks_of("A"))
    content = _fill_blocks(cache, old, seed=1)

    n = cache.swap_out("A")
    assert n == 3 and cache.is_swapped("A")
    assert cache.swapped_blocks("A") == 3
    assert cache.allocator.num_free == cache.allocator.capacity

    # claim the freed physical blocks and clobber their contents — the
    # host image, not the pool, must be what swap_in restores from
    cache.allocate("B", 64)                       # 4 blocks, LIFO overlap
    _fill_blocks(cache, cache.allocator.blocks_of("B"), seed=2)

    row = cache.swap_in("A")
    assert not cache.is_swapped("A")
    new = list(cache.allocator.blocks_of("A"))
    assert list(row[:3]) == new
    k = np.array(cache.pool["k"])
    v = np.array(cache.pool["v"])
    for o, fresh in zip(old, new):
        np.testing.assert_array_equal(k[:, fresh], content[o][0])
        np.testing.assert_array_equal(v[:, fresh], content[o][1])


def test_swap_out_never_frees_blocks_shared_with_others():
    cache = _cache()
    cache.allocate("A", 40)
    shared = cache.allocator.blocks_of("A")[0]
    cache.allocator.share(shared, "B")
    cache.swap_out("A")
    # A's exclusive blocks went back to the free list; the shared one
    # lost only A's reference and stays resident for B
    assert not cache.allocator.is_free(shared)
    assert cache.allocator.blocks_of("B") == [shared]
    assert cache.allocator.refcount(shared) == 1


def test_prefix_sharer_survives_victim_swap_out():
    cache = _cache()
    toks = np.arange(40, dtype=np.int32)          # 2 full blocks + tail
    cache.allocate_prefix("A", 40, toks)
    cache.register_progress("A", toks, 40)
    _, matched, shared = cache.allocate_prefix("B", 40, toks)
    assert shared == 2
    b_blocks = list(cache.allocator.blocks_of("B"))

    cache.swap_out("A")
    # B still reads the shared prefix blocks; its table is untouched
    assert cache.allocator.blocks_of("B") == b_blocks
    assert all(not cache.allocator.is_free(b) for b in b_blocks)
    cache.swap_in("A")
    cache.release("A")
    cache.release("B")


def test_absent_blocks_never_satisfy_prefix_matches():
    cache = _cache()
    toks = np.arange(40, dtype=np.int32)
    cache.allocate_prefix("A", 40, toks)
    cache.register_progress("A", toks, 40)
    stamped = _fill_blocks(cache, list(cache.allocator.blocks_of("A")),
                           seed=3)
    assert cache.match_prefix(toks) == 2

    cache.swap_out("A")
    # the index holds its own reference, so the registered blocks are
    # STILL RESIDENT (content intact) — a match here is safe by design
    assert cache.match_prefix(toks) == 2

    # force real absence: allocations evict the now-idle indexed blocks
    cache.allocate("B", 64)
    cache.allocate("C", 64)                       # 8 > 6 free -> evicts 2
    assert cache.match_prefix(toks) == 0, \
        "evicted (absent) blocks must never satisfy a prefix match"

    # and the victim still round-trips: swap_out imaged the content, so
    # the index dropping the blocks afterwards loses nothing
    cache.release("B")
    cache.release("C")
    cache.swap_in("A")
    k = np.array(cache.pool["k"])
    for o, fresh in zip(stamped, cache.allocator.blocks_of("A")):
        np.testing.assert_array_equal(k[:, fresh], stamped[o][0])


def test_release_while_swapped_reclaims_host_and_device():
    cache = _cache()
    cache.allocate("A", 40)
    cache.swap_out("A")
    assert cache.is_swapped("A")
    cache.release("A")
    assert not cache.is_swapped("A")
    assert cache.allocator.num_free == cache.allocator.capacity


def test_swap_in_oom_is_all_or_nothing():
    cache = _cache()
    cache.allocate("A", 40)
    cache.swap_out("A")
    cache.allocate("B", 64)
    cache.allocate("C", 64)                       # pool exhausted
    free_before = cache.allocator.num_free
    with pytest.raises(CacheOOM):
        cache.swap_in("A")
    assert cache.is_swapped("A")                  # image intact
    assert cache.allocator.num_free == free_before
    cache.release("B")
    cache.swap_in("A")                            # now it fits
    assert not cache.is_swapped("A")


def test_double_swap_out_and_swap_in_without_image_are_errors():
    cache = _cache()
    cache.allocate("A", 40)
    cache.swap_out("A")
    with pytest.raises(ValueError):
        cache.swap_out("A")
    with pytest.raises(ValueError):
        cache.swap_in("B")


# ---------------------------------------------------------------------------
# PagedBatcher scheduling (real engine, reduced config)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfg():
    return reduced_config(get_config("qwen2-1.5b"))


@pytest.fixture(scope="module")
def ref(cfg):
    """Uncontended reference: auto-sized pool, nothing ever preempts."""
    eng = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=48,
                                  max_batch=4, prefill_chunk=16,
                                  spec_decode=False, prefix_cache=False))
    batcher = PagedBatcher(eng, max_batch=4)
    yield eng, batcher
    batcher.close()


def _prompt(cfg, t, seed):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, (1, t)).astype(np.int32)


def _contended(cfg, ref_eng, num_blocks):
    """Small-pool engine sharing the reference params (token identity)."""
    eng = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=48,
                                  max_batch=4, prefill_chunk=16,
                                  num_blocks=num_blocks, spec_decode=False,
                                  prefix_cache=False),
                 params=ref_eng.params)
    return PagedBatcher(eng, max_batch=4)


def _wait(pred, timeout=120.0, what="condition"):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout:
            raise TimeoutError(f"timed out waiting for {what}")
        time.sleep(0.001)


def test_preempt_resume_token_identical_to_uncontended(cfg, ref):
    ref_eng, ref_b = ref
    victim_p, high_p = _prompt(cfg, 16, 3), _prompt(cfg, 16, 4)
    want_v = ref_b.submit(victim_p, max_new_tokens=24).result(timeout=120)
    want_h = ref_b.submit(high_p, max_new_tokens=8).result(timeout=120)

    # 4 usable blocks: the victim (3) leaves too little for the high (2)
    b = _contended(cfg, ref_eng, num_blocks=5)
    try:
        emitted = threading.Event()
        fv = b.submit(victim_p, max_new_tokens=24, priority=0,
                      on_token=lambda i, t: emitted.set())
        assert emitted.wait(120), "victim never started decoding"
        fh = b.submit(high_p, max_new_tokens=8, priority=1)
        got_h = fh.result(timeout=120)
        got_v = fv.result(timeout=120)
        assert b.stats["preemptions"] >= 1
        assert b.stats["swap_ins"] >= 1
        assert b.stats["swapped_blocks"] >= 3
        np.testing.assert_array_equal(got_v, want_v)
        np.testing.assert_array_equal(got_h, want_h)
    finally:
        b.close()


class _ManualDeadline:
    """A deadline the test flips, so no timing races decide the outcome."""

    def __init__(self):
        self.flag = False

    def expired(self):
        return self.flag


def test_swapped_victim_past_deadline_is_shed_with_reclaim(cfg, ref):
    ref_eng, _ = ref
    victim_p, high_p = _prompt(cfg, 16, 5), _prompt(cfg, 16, 6)
    b = _contended(cfg, ref_eng, num_blocks=5)
    try:
        dl = _ManualDeadline()
        emitted = threading.Event()
        fv = b.submit(victim_p, max_new_tokens=24, priority=0, deadline=dl,
                      on_token=lambda i, t: emitted.set())
        assert emitted.wait(120), "victim never started decoding"
        # a LONG high keeps the pool full, so the victim stays paged out
        fh = b.submit(high_p, max_new_tokens=24, priority=1)
        _wait(lambda: b.stats["preemptions"] >= 1, what="preemption")
        dl.flag = True
        with pytest.raises(ShedError, match="swapped out"):
            fv.result(timeout=120)
        fh.result(timeout=120)
        # shed while paged out reclaimed BOTH tiers: no host image left,
        # every device block back on the free list
        _wait(lambda: b.cache.num_free_blocks == b.cache.allocator.capacity,
              what="block reclaim")
        assert not b.cache._swapped
        assert not b._preempted
    finally:
        b.close()


def test_victim_selection_lowest_priority_most_blocks_first(cfg, ref):
    ref_eng, ref_b = ref
    big_p, small_p, high_p = (_prompt(cfg, 16, s) for s in (7, 8, 9))
    want_big = ref_b.submit(big_p, max_new_tokens=48).result(timeout=120)
    want_small = ref_b.submit(small_p, max_new_tokens=16).result(timeout=120)
    want_high = ref_b.submit(high_p, max_new_tokens=32).result(timeout=120)

    # 8 usable blocks: big holds 4, small holds 2, the high needs 3 > 2
    b = _contended(cfg, ref_eng, num_blocks=9)
    try:
        victims = []
        orig = b._preempt
        b._preempt = lambda req: (victims.append(req), orig(req))[1]
        counts = {"big": 0, "small": 0}

        def hook(name):
            def on_token(i, t):
                counts[name] += 1
            return on_token

        f_big = b.submit(big_p, max_new_tokens=48, priority=0,
                         on_token=hook("big"))
        f_small = b.submit(small_p, max_new_tokens=16, priority=0,
                           on_token=hook("small"))
        _wait(lambda: counts["big"] >= 1 and counts["small"] >= 1,
              what="both lows decoding")
        f_high = b.submit(high_p, max_new_tokens=32, priority=1)
        got_high = f_high.result(timeout=120)
        got_small = f_small.result(timeout=120)
        got_big = f_big.result(timeout=120)

        # equal priority -> the request holding the MOST blocks is paged
        # out first (fewest victims for the most relief), and it alone
        # already covers the high's need
        assert victims, "admission never preempted"
        assert victims[0].future is f_big
        np.testing.assert_array_equal(got_big, want_big)
        np.testing.assert_array_equal(got_small, want_small)
        np.testing.assert_array_equal(got_high, want_high)
    finally:
        b.close()


# ---------------------------------------------------------------------------
# SLO controller (pure host-side state, no traffic needed)
# ---------------------------------------------------------------------------


def test_slo_controller_halves_budget_on_tpot_pressure(ref):
    eng, _ = ref
    b = PagedBatcher(eng, max_batch=4)
    try:
        b.max_step_tokens = 64
        b._tpot_obs.extend([(0.2, 0.1)] * 8)      # 100% violations
        b._slo_adjust()
        assert b.max_step_tokens == 32
        assert b.stats["slo_adjustments"] == 1
        assert not b._tpot_obs, "window must reset after a move"
        for _ in range(10):                        # clamp floor
            b._tpot_obs.extend([(0.2, 0.1)] * 8)
            b._slo_adjust()
        assert b.max_step_tokens == b.max_batch + 1
    finally:
        b.close()


def test_slo_controller_doubles_budget_on_ttft_pressure(ref):
    eng, _ = ref
    b = PagedBatcher(eng, max_batch=4)
    try:
        b.max_step_tokens = 16
        b._ttft_obs.extend([(0.5, 0.1)] * 8)
        b._slo_adjust()
        assert b.max_step_tokens == 32
        for _ in range(10):                        # clamp ceiling
            b._ttft_obs.extend([(0.5, 0.1)] * 8)
            b._slo_adjust()
        assert b.max_step_tokens == b._step_budget_cap
    finally:
        b.close()


def test_slo_controller_holds_below_violation_threshold(ref):
    eng, _ = ref
    b = PagedBatcher(eng, max_batch=4)
    try:
        b.max_step_tokens = 64
        b._tpot_obs.extend([(0.2, 0.1)] + [(0.05, 0.1)] * 7)   # 12.5%
        b._slo_adjust()
        assert b.max_step_tokens == 64
        assert b.stats["slo_adjustments"] == 0
        assert len(b._tpot_obs) == 8, "no move -> window keeps filling"
    finally:
        b.close()


# ---------------------------------------------------------------------------
# Stats presence regression
# ---------------------------------------------------------------------------

REQUIRED_KEYS = {"requests", "rows", "shed", "decode_steps", "batched_rows",
                 "prefill_chunks", "mixed_steps", "admitted_in_flight",
                 "dense_fallbacks", "worker_errors", "prefix_hits",
                 "prefix_tokens_reused", "cow_copies", "spec_steps",
                 "spec_proposed", "spec_accepted", "preemptions",
                 "swapped_blocks", "swap_ins", "slo_violations",
                 "slo_adjustments"}


def test_paged_stats_keys_present_from_construction(ref):
    eng, _ = ref
    b = PagedBatcher(eng, max_batch=4)
    try:
        assert REQUIRED_KEYS <= set(b.stats)
        assert all(v == 0 for v in b.stats.values()), \
            "counters must start at zero, not appear on first increment"
        snap = b.collect_stats()
        assert set(b.stats) <= set(snap)
        for gauge in ("active_requests", "queued_requests",
                      "preempted_requests", "free_blocks",
                      "max_step_tokens"):
            assert gauge in snap
    finally:
        b.close()


def test_dense_stats_snapshot_has_queue_gauge(ref):
    eng, _ = ref
    b = ContinuousBatcher(eng, max_batch=4, window_s=0.01)
    try:
        snap = b.collect_stats()
        assert set(b.stats) <= set(snap)
        assert "queued_requests" in snap
    finally:
        b.close()
