"""End-to-end dry-run integration: run launch/dryrun.py as a subprocess
(so the 512-device XLA flag applies) for one fast cell on both meshes, and
check the JSON record has every §Roofline input."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_one_cell_subprocess(tmp_path, mesh):
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src
    env.pop("XLA_FLAGS", None)  # dryrun.py must set it itself
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "rwkv6-7b", "--shape", "long_500k",
         "--mesh", mesh, "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(tmp_path / f"rwkv6-7b__long_500k__{mesh}__baseline"
                                    ".json"))
    assert rec["status"] == "ok"
    assert rec["chips"] == (512 if mesh == "multi" else 256)
    for key in ("flops_per_device", "bytes_per_device", "collective_bytes",
                "compute_s", "memory_s", "collective_s", "dominant",
                "model_flops", "useful_ratio", "roofline_fraction"):
        assert key in rec, key
    assert rec["flops_per_device"] > 0
    assert rec["compile_s"] > 0


def test_dryrun_list_enumerates_40_cells(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--list"],
        env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0
    lines = [ln for ln in r.stdout.splitlines() if ln.strip()]
    assert len(lines) == 40
    assert sum(1 for ln in lines if "SKIP" in ln) == 8
