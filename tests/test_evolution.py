"""Schema evolution rules (paper Table 9), exercised on real wire bytes."""
import pytest

from repro.core import types as T, wire


def test_message_add_field_old_reader_ignores():
    V1 = T.Message("M", [T.Field("a", T.INT32, tag=1)])
    V2 = T.Message("M", [T.Field("a", T.INT32, tag=1),
                         T.Field("b", T.STRING, tag=2)])
    new_bytes = wire.encode(V2, {"a": 5, "b": "x"})
    # old reader: tag 2 unknown -> by default skips to end of message
    old = wire.decode(V1, new_bytes)
    assert old["a"] == 5


def test_message_add_field_new_reader_reads_old():
    V1 = T.Message("M", [T.Field("a", T.INT32, tag=1)])
    V2 = T.Message("M", [T.Field("a", T.INT32, tag=1),
                         T.Field("b", T.STRING, tag=2)])
    old_bytes = wire.encode(V1, {"a": 5})
    new = wire.decode(V2, old_bytes)
    assert new == {"a": 5}
    assert "b" not in new


def test_message_unknown_tag_ordering():
    """If the new field is encoded BEFORE known tags, an old reader with a
    skip entry still reads the rest."""
    V2 = T.Message("M", [T.Field("b", T.STRING, tag=2),
                         T.Field("a", T.INT32, tag=1)])
    V1 = T.Message("M", [T.Field("a", T.INT32, tag=1)])
    # register a skipper for retired/unknown tag 2 (string)
    V1.retired_tag_skippers = {
        2: lambda r: r.take(r.u32() + 1)}
    b = wire.encode(V2, {"b": "zzz", "a": 9})
    assert wire.decode(V1, b)["a"] == 9


def test_message_rename_field_safe():
    V1 = T.Message("M", [T.Field("old_name", T.INT32, tag=1)])
    V2 = T.Message("M", [T.Field("new_name", T.INT32, tag=1)])
    b = wire.encode(V1, {"old_name": 3})
    assert wire.decode(V2, b) == {"new_name": 3}  # names not on wire


def test_struct_field_changes_break():
    """Structs are positional: adding a field changes every later offset."""
    V1 = T.Struct("S", [T.Field("a", T.UINT32)])
    V2 = T.Struct("S", [T.Field("a", T.UINT32), T.Field("b", T.UINT32)])
    b1 = wire.encode(V1, {"a": 1})
    with pytest.raises(T.DecodeError):
        wire.decode(V2, b1)  # overruns: old data too short


def test_struct_reorder_breaks_silently_differs():
    V1 = T.Struct("S", [T.Field("a", T.UINT8), T.Field("b", T.UINT16)])
    V2 = T.Struct("S", [T.Field("b", T.UINT16), T.Field("a", T.UINT8)])
    b = wire.encode(V1, {"a": 1, "b": 2})
    out = wire.decode(V2, b)
    assert out != {"a": 1, "b": 2}  # wrong values, no error: breaking


def test_union_add_branch_safe():
    V1 = T.Union("U", [T.Branch("A", 1, T.Struct("A", [T.Field("x", T.INT32)]))])
    V2 = T.Union("U", [T.Branch("A", 1, T.Struct("A", [T.Field("x", T.INT32)])),
                       T.Branch("B", 2, T.Struct("B", [T.Field("y", T.INT32)]))])
    b = wire.encode(V1, ("A", {"x": 1}))
    assert wire.decode(V2, b).name == "A"


def test_union_remove_branch_breaks():
    V2 = T.Union("U", [T.Branch("A", 1, T.Struct("A", [T.Field("x", T.INT32)])),
                       T.Branch("B", 2, T.Struct("B", [T.Field("y", T.INT32)]))])
    V1 = T.Union("U", [T.Branch("A", 1, T.Struct("A", [T.Field("x", T.INT32)]))])
    b = wire.encode(V2, ("B", {"y": 1}))
    with pytest.raises(T.DecodeError):
        wire.decode(V1, b)


def test_enum_add_value_safe_remove_breaks():
    E2 = T.Enum("E", {"Z": 0, "A": 1, "B": 2}, base=T.UINT8)
    E1 = T.Enum("E", {"Z": 0, "A": 1}, base=T.UINT8)
    b = wire.encode(E2, 2)
    v = wire.decode(E1, b)   # decodes to raw int; name unknown
    assert v == 2
    assert E1.name_of(2) is None


def test_checkpoint_manifest_evolution():
    """Our checkpoint Manifest is a message: a reader built before
    `data_cursor` existed still reads step/shards."""
    from repro.checkpoint import format as F
    OldManifest = T.Message("Manifest", [
        T.Field("step", T.UINT64, tag=1),
        T.Field("created", T.TIMESTAMP, tag=2),
        T.Field("shards", T.Array(F.ShardInfo), tag=3),
    ])
    blob = F.encode_manifest(42, [{"path": "s", "tensor_count": 1,
                                   "byte_size": 10}],
                             data_cursor=999, mesh_shape=(16, 16),
                             mesh_axes=("data", "model"))
    old = wire.decode(OldManifest, blob)
    assert old["step"] == 42
    new = F.decode_manifest(blob)
    assert new["data_cursor"] == 999
