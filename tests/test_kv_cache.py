"""Paged KV cache: allocator invariants (property-based) + layout rules."""
import numpy as np
import pytest

from repro.serving.kv_cache import (BlockAllocator, CacheOOM, PagedKVCache,
                                    aligned_block_size)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # optional dependency, like tests/test_property.py
    HAS_HYPOTHESIS = False


# -- deterministic unit coverage ----------------------------------------------

def test_alloc_free_roundtrip():
    a = BlockAllocator(9)
    assert a.capacity == 8 and a.num_free == 8
    b1 = a.alloc(3, "r1")
    b2 = a.alloc(5, "r2")
    assert 0 not in b1 + b2          # null block never handed out
    assert len(set(b1) | set(b2)) == 8
    assert a.num_free == 0
    with pytest.raises(CacheOOM):
        a.alloc(1, "r3")
    assert a.free("r1") == 3
    assert a.num_free == 3
    b3 = a.alloc(3, "r3")
    assert set(b3) == set(b1)        # LIFO reuse
    assert a.free("unknown") == 0    # releasing a non-owner is a no-op


def test_aligned_block_size_rounds_up():
    # f32 head_dim 16: any block size is 64B-aligned already
    assert aligned_block_size(16, 16, "float32") == 16
    # bf16 head_dim 16 = 32B rows: odd block sizes round up
    assert aligned_block_size(3, 16, "bfloat16") == 4
    # f32 head_dim 20 = 80B rows: need lcm with 64
    bs = aligned_block_size(1, 20, "float32")
    assert (bs * 20 * 4) % 64 == 0


def test_paged_cache_tables_and_release():
    c = PagedKVCache(num_layers=2, num_kv_heads=2, head_dim=16,
                     cache_len=64, block_size=16, max_concurrent=2)
    assert c.blocks_per_seq == 4
    assert c.layout.block_bytes % 64 == 0
    t1 = c.allocate("a", 40)         # 3 blocks
    assert t1.shape == (4,) and (t1[:3] > 0).all() and t1[3] == 0
    with pytest.raises(ValueError):
        c.allocate("a", 8)           # double allocation for one owner
    t2 = c.allocate("b", 64)
    assert not set(t1[:3]) & set(t2)
    assert c.release("a") == 3
    assert c.can_allocate(64)
    k = c.pool["k"]
    assert k.shape == (2, c.layout.num_blocks, 2, 16, 16)


def test_oom_is_all_or_nothing():
    c = PagedKVCache(num_layers=1, num_kv_heads=1, head_dim=16,
                     cache_len=64, block_size=16, num_blocks=4)
    c.allocate("a", 32)              # 2 of 3 usable blocks
    free_before = c.num_free_blocks
    with pytest.raises(CacheOOM):
        c.allocate("b", 64)          # needs 4
    assert c.num_free_blocks == free_before   # nothing leaked
    c.allocate("b", 16)              # smaller request still fits


# -- property test: alloc/free/evict never double-assigns ---------------------

if HAS_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("alloc"), st.integers(0, 5),
                      st.integers(0, 7)),     # (op, nblocks, owner)
            st.tuples(st.just("free"), st.integers(0, 7),
                      st.integers(0, 7)),     # (op, owner, _)
        ),
        max_size=60)

    @settings(max_examples=200, deadline=None)
    @given(_ops)
    def test_allocator_never_double_assigns(ops):
        """Random alloc/free/evict interleavings keep every block owned by
        at most one request, conserve capacity exactly, and a shed owner
        gets ALL of its blocks back into circulation."""
        a = BlockAllocator(12)
        model = {}                       # owner -> set(blocks), the oracle
        for op, x, y in ops:
            if op == "alloc":
                held = sum(len(v) for v in model.values())
                owner = f"r{y}"
                try:
                    got = a.alloc(x, owner)
                except CacheOOM:
                    assert x > a.capacity - held
                    continue
                # no overlap with anything outstanding, no null block
                flat = set().union(*model.values()) if model else set()
                assert not set(got) & flat
                assert 0 not in got
                assert len(set(got)) == x
                model.setdefault(owner, set()).update(got)
            else:
                owner = f"r{x}"
                expect = len(model.pop(owner, set()))
                assert a.free(owner) == expect   # shed returns ALL blocks
            held = sum(len(v) for v in model.values())
            assert a.num_free == a.capacity - held   # conservation
        # draining every owner restores full capacity
        for owner in list(model):
            a.free(owner)
        assert a.num_free == a.capacity
else:  # pragma: no cover - CI installs hypothesis
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocator_never_double_assigns():
        pass
