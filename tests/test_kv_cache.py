"""Paged KV cache: allocator invariants (property-based) + layout rules +
refcounted sharing / prefix-cache / copy-on-write bookkeeping."""
import numpy as np
import pytest

from repro.serving.kv_cache import (BlockAllocator, CacheOOM, PagedKVCache,
                                    aligned_block_size, block_keys)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # optional dependency, like tests/test_property.py
    HAS_HYPOTHESIS = False


# -- deterministic unit coverage ----------------------------------------------

def test_alloc_free_roundtrip():
    a = BlockAllocator(9)
    assert a.capacity == 8 and a.num_free == 8
    b1 = a.alloc(3, "r1")
    b2 = a.alloc(5, "r2")
    assert 0 not in b1 + b2          # null block never handed out
    assert len(set(b1) | set(b2)) == 8
    assert a.num_free == 0
    with pytest.raises(CacheOOM):
        a.alloc(1, "r3")
    assert a.free("r1") == 3
    assert a.num_free == 3
    b3 = a.alloc(3, "r3")
    assert set(b3) == set(b1)        # LIFO reuse
    assert a.free("unknown") == 0    # releasing a non-owner is a no-op


def test_aligned_block_size_rounds_up():
    # f32 head_dim 16: any block size is 64B-aligned already
    assert aligned_block_size(16, 16, "float32") == 16
    # bf16 head_dim 16 = 32B rows: odd block sizes round up
    assert aligned_block_size(3, 16, "bfloat16") == 4
    # f32 head_dim 20 = 80B rows: need lcm with 64
    bs = aligned_block_size(1, 20, "float32")
    assert (bs * 20 * 4) % 64 == 0


def test_paged_cache_tables_and_release():
    c = PagedKVCache(num_layers=2, num_kv_heads=2, head_dim=16,
                     cache_len=64, block_size=16, max_concurrent=2)
    assert c.blocks_per_seq == 4
    assert c.layout.block_bytes % 64 == 0
    t1 = c.allocate("a", 40)         # 3 blocks
    assert t1.shape == (4,) and (t1[:3] > 0).all() and t1[3] == 0
    with pytest.raises(ValueError):
        c.allocate("a", 8)           # double allocation for one owner
    t2 = c.allocate("b", 64)
    assert not set(t1[:3]) & set(t2)
    assert c.release("a") == 3
    assert c.can_allocate(64)
    k = c.pool["k"]
    assert k.shape == (2, c.layout.num_blocks, 2, 16, 16)


def test_oom_is_all_or_nothing():
    c = PagedKVCache(num_layers=1, num_kv_heads=1, head_dim=16,
                     cache_len=64, block_size=16, num_blocks=4)
    c.allocate("a", 32)              # 2 of 3 usable blocks
    free_before = c.num_free_blocks
    with pytest.raises(CacheOOM):
        c.allocate("b", 64)          # needs 4
    assert c.num_free_blocks == free_before   # nothing leaked
    c.allocate("b", 16)              # smaller request still fits


def test_alloc_invariant_path_is_all_or_nothing():
    """Regression: if the double-assign invariant fires mid-alloc, the
    already-popped blocks must go back on the free list and no partial
    ownership may be recorded — the old code leaked both."""
    a = BlockAllocator(8)
    held = a.alloc(2, "r1")
    a._free.append(held[0])          # simulate free-list corruption
    free_before = list(a._free)
    with pytest.raises(AssertionError):
        a.alloc(len(free_before), "victim")
    assert a._free == free_before    # every popped block restored, in order
    assert a.blocks_of("victim") == []
    assert a.refcount(held[0]) == 1  # untouched beyond the corruption itself


def test_blocks_needed_rejects_oversized_requests():
    """Regression: blocks_needed used to clamp at blocks_per_seq, so a
    request longer than cache_len got a silently-truncated table whose
    later tokens would alias the early blocks."""
    c = PagedKVCache(num_layers=1, num_kv_heads=1, head_dim=16,
                     cache_len=64, block_size=16, num_blocks=8)
    assert c.blocks_needed(64) == 4          # exactly full is fine
    with pytest.raises(ValueError):
        c.blocks_needed(65)
    assert not c.can_allocate(65)            # reject, don't truncate
    free_before = c.num_free_blocks
    with pytest.raises(ValueError):
        c.allocate("a", 100)
    assert c.num_free_blocks == free_before  # nothing leaked


# -- refcounted sharing -------------------------------------------------------

def test_share_and_drop_refcounts():
    a = BlockAllocator(6)
    (b1, b2) = a.alloc(2, "r1")
    a.share(b1, "r2")
    assert a.refcount(b1) == 2 and a.refcount(b2) == 1
    assert a.free("r1") == 2         # two references released...
    assert a.refcount(b1) == 1       # ...but the shared block stays live
    assert not a.is_free(b1) and a.is_free(b2)
    assert a.drop("r2", b1)          # last reference -> free list
    assert a.is_free(b1) and a.refcount(b1) == 0
    assert a.num_free == a.capacity
    with pytest.raises(ValueError):
        a.drop("r2", b1)             # no reference held any more
    with pytest.raises(ValueError):
        a.share(b1, "r3")            # free blocks cannot be shared


# -- prefix cache: content-hash chain, LRU retention, copy-on-write -----------

def _cache(**kw):
    kw.setdefault("num_blocks", 12)
    return PagedKVCache(num_layers=1, num_kv_heads=1, head_dim=16,
                        cache_len=128, block_size=16, **kw)


def test_block_keys_chain_position_dependence():
    toks = np.arange(48, dtype=np.int32)
    keys = block_keys(toks, 16)
    assert len(keys) == 3            # only FULL blocks get keys
    assert len(block_keys(toks[:47], 16)) == 2
    # same content at a different chain position -> different key
    swapped = np.concatenate([toks[16:32], toks[:16], toks[32:48]])
    assert block_keys(swapped, 16)[2] != keys[2]
    assert block_keys(toks, 16) == keys  # deterministic


def test_prefix_match_register_release_reuse():
    c = _cache()
    toks = (np.arange(40, dtype=np.int32) * 7) % 13
    row, matched, shared = c.allocate_prefix("a", 48, toks)
    assert (matched, shared) == (0, 0)   # cold
    assert c.match_prefix(toks) == 0
    c.register_progress("a", toks, 40)   # 2 full blocks written + indexed
    assert c.match_prefix(toks) == 2
    c.release("a")
    # cached-but-unreferenced: out of the free list, but reclaimable
    assert c.reclaimable == 2
    assert c.num_free_blocks + c.reclaimable == c.allocator.capacity
    row2, matched2, shared2 = c.allocate_prefix("b", 48, toks)
    assert (matched2, shared2) == (32, 2)
    assert row2[0] == row[0] and row2[1] == row[1]   # same physical blocks
    assert c.allocator.refcount(row2[0]) == 2        # cache + request
    assert c.allocator.refcount(int(row2[2])) == 1   # tail is never shared
    c.release("b")


def test_prefix_match_clamps_to_leave_one_token():
    """A fully-matched block-aligned prompt still leaves >= 1 token to
    process (the step producing the first logits), and the write there
    lands in a shared block -> ensure_private copy-on-writes it."""
    c = _cache()
    toks = np.arange(32, dtype=np.int32)
    c.allocate_prefix("a", 40, toks)
    c.register_progress("a", toks, 32)
    c.release("a")
    row, matched, shared = c.allocate_prefix("b", 40, toks)
    assert (matched, shared) == (31, 2)   # not 32: last token re-processed
    pair = c.ensure_private("b", 1)       # boundary block is shared
    assert pair is not None
    old, new = pair
    assert old == row[1] and new != old
    assert c.table_row("b")[1] == new
    assert c.allocator.refcount(old) == 1     # cache keeps the original
    assert c.allocator.refcount(new) == 1     # request owns the copy
    assert c.ensure_private("b", 1) is None   # already private
    assert c.ensure_private("b", 2) is None   # tail was never shared
    c.release("b")


def test_prefix_lru_eviction_order_and_pressure():
    """Eviction frees least-recently-USED entries first, skips blocks
    live requests still reference, and runs automatically when an
    allocation would otherwise CacheOOM."""
    c = _cache(num_blocks=8)         # capacity 7
    ta = np.arange(32, dtype=np.int32)
    tb = np.arange(32, 64, dtype=np.int32)
    for owner, toks in (("a", ta), ("b", tb)):
        c.allocate_prefix(owner, 32, toks)
        c.register_progress(owner, toks, 32)
        c.release(owner)
    assert c.reclaimable == 4 and c.num_free_blocks == 3
    c.allocate_prefix("a2", 32, ta)  # touch A: now B is least-recent
    # 5 fresh blocks forces eviction; A's are pinned, so B's two go first
    c.allocate("big", 80)
    assert c.match_prefix(tb) == 0   # B evicted
    assert c.match_prefix(ta) == 2   # A survived (live reference)
    assert c.prefix.evictions == 2
    c.release("a2")
    c.release("big")
    # unsatisfiable even after eviction still raises, all-or-nothing
    c.allocate("full", 96)           # 6 blocks; 1 free + A's 2 evictable
    with pytest.raises(CacheOOM):
        c.allocate("more", 48)
    c.release("full")


def test_prefix_lru_capacity_knob_keeps_matchable_head():
    c = _cache(prefix_lru_blocks=2)
    toks = np.arange(64, dtype=np.int32)
    c.allocate_prefix("a", 64, toks)
    c.register_progress("a", toks, 64)   # 4 full blocks -> cap 2 retained
    c.release("a")
    assert len(c.prefix) == 2
    assert c.reclaimable == 2
    # eviction is leaf-first: the chain is trimmed from the TAIL, so the
    # retained blocks are the head — still matchable as a partial hit
    # (dropping the head instead would leave unmatchable dead weight)
    assert c.match_prefix(toks) == 2
    assert c.match_prefix(toks[:32]) == 2


def test_prefix_disabled_is_inert():
    c = _cache(prefix_cache=False)
    toks = np.arange(48, dtype=np.int32)
    row, matched, shared = c.allocate_prefix("a", 48, toks)
    assert (matched, shared) == (0, 0)
    assert c.register_progress("a", toks, 48) == 0
    c.release("a")
    assert c.reclaimable == 0
    assert c.num_free_blocks == c.allocator.capacity
    assert c.match_prefix(toks) == 0


# -- property test: alloc/free/evict never double-assigns ---------------------

if HAS_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("alloc"), st.integers(0, 5),
                      st.integers(0, 7)),     # (op, nblocks, owner)
            st.tuples(st.just("free"), st.integers(0, 7),
                      st.integers(0, 7)),     # (op, owner, _)
        ),
        max_size=60)

    @settings(max_examples=200, deadline=None)
    @given(_ops)
    def test_allocator_never_double_assigns(ops):
        """Random alloc/free/evict interleavings keep every block owned by
        at most one request, conserve capacity exactly, and a shed owner
        gets ALL of its blocks back into circulation."""
        a = BlockAllocator(12)
        model = {}                       # owner -> set(blocks), the oracle
        for op, x, y in ops:
            if op == "alloc":
                held = sum(len(v) for v in model.values())
                owner = f"r{y}"
                try:
                    got = a.alloc(x, owner)
                except CacheOOM:
                    assert x > a.capacity - held
                    continue
                # no overlap with anything outstanding, no null block
                flat = set().union(*model.values()) if model else set()
                assert not set(got) & flat
                assert 0 not in got
                assert len(set(got)) == x
                model.setdefault(owner, set()).update(got)
            else:
                owner = f"r{x}"
                expect = len(model.pop(owner, set()))
                assert a.free(owner) == expect   # shed returns ALL blocks
            held = sum(len(v) for v in model.values())
            assert a.num_free == a.capacity - held   # conservation
        # draining every owner restores full capacity
        for owner in list(model):
            a.free(owner)
        assert a.num_free == a.capacity
    _rc_ops = st.lists(
        st.tuples(st.sampled_from(["alloc", "share", "drop", "free", "cow"]),
                  st.integers(0, 5), st.integers(0, 7)),
        max_size=80)

    @settings(max_examples=200, deadline=None)
    @given(_rc_ops)
    def test_refcounted_allocator_invariants(ops):
        """Random share/release/COW interleavings: refcounts never go
        negative, a block is free iff its refcount is 0, and pool
        capacity is conserved exactly at every step."""
        a = BlockAllocator(12)
        refs = {}                      # block -> refcount, the oracle
        owned = {}                     # owner -> blocks (with multiplicity)
        for op, x, y in ops:
            owner = f"r{y}"
            if op == "alloc":
                try:
                    got = a.alloc(x, owner)
                except CacheOOM:
                    assert x > a.num_free
                    continue
                for b in got:
                    assert refs.get(b, 0) == 0   # fresh blocks only
                    refs[b] = 1
                owned.setdefault(owner, []).extend(got)
            elif op == "share":
                live = sorted(refs)
                if not live:
                    continue
                b = live[x % len(live)]
                a.share(b, owner)
                refs[b] += 1
                owned.setdefault(owner, []).append(b)
            elif op == "drop":
                blocks = owned.get(owner)
                if not blocks:
                    continue
                b = blocks[x % len(blocks)]
                went_free = a.drop(owner, b)
                blocks.remove(b)
                refs[b] -= 1
                assert refs[b] >= 0
                assert went_free == (refs[b] == 0)
                if refs[b] == 0:
                    del refs[b]
            elif op == "cow":
                # the engine's copy-on-write: a private replacement block
                # is taken, then the shared original's reference dropped
                shared = [b for b in owned.get(owner, ()) if refs[b] > 1]
                if not shared:
                    continue
                b = shared[x % len(shared)]
                try:
                    new = a.alloc(1, owner)[0]
                except CacheOOM:
                    continue
                refs[new] = 1
                owned[owner].append(new)
                a.drop(owner, b)
                owned[owner].remove(b)
                refs[b] -= 1
                assert refs[b] >= 1   # someone else still reads it
            else:  # free: release the owner wholesale (retire/shed path)
                blocks = owned.pop(owner, [])
                assert a.free(owner) == len(blocks)
                for b in blocks:
                    refs[b] -= 1
                    assert refs[b] >= 0
                    if refs[b] == 0:
                        del refs[b]
            # global invariants after EVERY op
            assert a.num_free == a.capacity - len(refs)   # conservation
            for b, rc in refs.items():
                assert a.refcount(b) == rc and rc > 0
                assert not a.is_free(b)                   # free iff rc == 0
        for owner in list(owned):
            a.free(owner)
        assert a.num_free == a.capacity
else:  # pragma: no cover - CI installs hypothesis
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocator_never_double_assigns():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_refcounted_allocator_invariants():
        pass
