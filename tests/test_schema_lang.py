"""Schema language (§5) + compiler + decorators + descriptor tests."""
import os

import numpy as np
import pytest

from repro.core import types as T
from repro.core.compiler import CompileError, compile_source
from repro.core.codegen import generate_python, load_generated
from repro.core.decorators import LuaError, run_lua
from repro.core.descriptor import (decode_descriptor_set,
                                   encode_descriptor_set, topological_order)
from repro.core.hashing import lowbias32, method_id, murmur3_lowbias32
from repro.core.parser import parse_duration, parse_iso8601

BASIC = '''
edition = "2026"
package my.app
import "bebop/decorators.bop"

/// Doc comment captured.
struct Point { x: float32; y: float32; }

enum Status : uint8 { UNKNOWN = 0; ACTIVE = 1; }

message Profile {
  id(1): uuid;
  @indexed(unique=true)
  email(2): string;
  scores(3): float32[];
  status(4): Status;
}

union Result {
  Success(1): { value: string; };
  Error(2): { code: int32; message: string; };
}

const int32 MAX_SIZE = 0x400;
const duration TIMEOUT = "30s";
const timestamp EPOCH = "1970-01-01T00:00:00Z";
const byte[] MAGIC = b"\\x89PNG";
const string HOST = "localhost";

service Base { Ping(Point): Point; }
service Chat with Base {
  Send(Profile): Profile;
  Subscribe(Point): stream Profile;
  Upload(stream Point): Profile;
  Talk(stream Point): stream Point;
}
'''


@pytest.fixture(scope="module")
def schema():
    return compile_source(BASIC, filename="basic.bop")


def test_definitions_present(schema):
    for name in ["Point", "Status", "Profile", "Result", "MAX_SIZE",
                 "TIMEOUT", "EPOCH", "MAGIC", "HOST", "Base", "Chat"]:
        assert name in schema.definitions, name
    assert schema.package == "my.app"
    assert schema["Point"].doc == "Doc comment captured."


def test_constants(schema):
    assert schema["MAX_SIZE"].value == 1024
    assert schema["TIMEOUT"].value == T.Duration(30, 0)
    assert schema["EPOCH"].value == T.Timestamp(0, 0, 0)
    assert bytes(schema["MAGIC"].value.tobytes()) == b"\x89PNG"


def test_service_composition_and_ids(schema):
    chat = schema["Chat"]
    names = [m.name for m in chat.methods]
    assert names[0] == "Ping"  # composed in via `with`
    kinds = {m.name: m.kind for m in chat.methods}
    assert kinds == {"Ping": "unary", "Send": "unary",
                     "Subscribe": "server_stream", "Upload": "client_stream",
                     "Talk": "duplex"}
    for m in chat.methods:
        assert m.id == method_id("Chat", m.name)


def test_decorator_export(schema):
    email = schema["Profile"].field("email")
    exp = email.decorators[0].exported
    assert exp["index_name"] == "Profile_email_idx"
    assert exp["is_unique"] is True


def test_validate_block_rejects():
    bad = '''
import "bebop/decorators.bop"
struct S { @validate_range(min=5.0, max=1.0) x: float32; }
'''
    with pytest.raises(T.SchemaError):
        compile_source(bad)


def test_decorator_target_mismatch():
    bad = '''
import "bebop/decorators.bop"
@indexed(unique=true)
struct S { x: float32; }
'''
    with pytest.raises(T.SchemaError):
        compile_source(bad)


def test_import_cycle_detected():
    loader = lambda path, imp: 'import "a.bop"\nstruct B { x: int32; }'  # noqa
    with pytest.raises(CompileError):
        compile_source('import "a.bop"\nstruct A { b: int32; }',
                       filename="a.bop", loader=loader)


def test_env_substitution():
    os.environ["BEBOP_TEST_VAR"] = "hello"
    s = compile_source('const string X = "$(BEBOP_TEST_VAR)/suffix";')
    assert s["X"].value == "hello/suffix"


def test_duration_literals():
    assert parse_duration("1h30m") == T.Duration(5400, 0)
    assert parse_duration("500ms") == T.Duration(0, 500_000_000)
    assert parse_duration("10us") == T.Duration(0, 10_000)
    assert parse_duration("-2s") == T.Duration(-2, 0)
    with pytest.raises(T.SchemaError):
        parse_duration("10 parsecs")


def test_iso8601_ms_precision_offset():
    ts = parse_iso8601("2024-01-15T10:30:00+12:00:01.133")
    assert ts.offset_ms == (12 * 3600 + 1) * 1000 + 133
    ts2 = parse_iso8601("2024-01-15T10:30:00.5Z")
    assert ts2.ns == 500_000_000


def test_nested_visibility():
    src = '''
struct Outer {
  struct Inner { a: int32; }
  export struct Pub { b: int32; }
  i: Outer.Inner;
}
local struct Priv { x: int32; }
'''
    s = compile_source(src)
    assert s["Outer.Inner"].visibility == "local"
    assert s["Outer.Pub"].visibility == "export"
    assert s["Priv"].visibility == "local"


def test_codegen_roundtrip(schema):
    mod = load_generated(schema, "basic_gen")
    p = mod.Point(x=1.5, y=-2.5)
    q = mod.Point.decode(p.encode())
    assert q.x == 1.5 and q.y == -2.5
    prof = mod.Profile(email="a@b.c", scores=np.asarray([0.5, 1.5], "f4"))
    back = mod.Profile.decode(prof.encode())
    assert back.email == "a@b.c"
    assert np.allclose(back.scores, [0.5, 1.5])
    assert back.id is None  # absent field


def test_codegen_source_is_python(schema):
    src = generate_python(schema)
    compile(src, "<gen>", "exec")


def test_descriptor_topological_and_roundtrip(schema):
    order = topological_order(schema)
    assert order.index("Status") < order.index("Profile")
    blob = encode_descriptor_set([schema])
    ds = decode_descriptor_set(blob)
    defs = {d["name"]: d for d in ds["schemas"][0]["definitions"]}
    assert defs["Profile"]["kind"] == 3  # MESSAGE
    svc = defs["Chat"]["service_def"]["methods"]
    assert all("routing_id" in m for m in svc)


def test_murmur3_lowbias32_stable():
    a = murmur3_lowbias32(b"/Chat/Send")
    assert a == murmur3_lowbias32(b"/Chat/Send")
    assert a != murmur3_lowbias32(b"/Chat/Send2")
    assert 0 <= a < 2 ** 32
    # lowbias32 reference vector (identity on 0 is not expected)
    assert lowbias32(0) == 0
    assert lowbias32(1) != 1


def test_mini_lua():
    env = {"target": {"kind": "FIELD", "name": "email", "parent": "User"},
           "unique": True}
    out = run_lua('''
      local t, f = target.parent, target.name
      return { idx = t .. "_" .. f, u = unique or false, n = 1 + 2 * 3 }
    ''', env)
    assert out == {"idx": "User_email", "u": True, "n": 7}
    with pytest.raises(LuaError):
        run_lua('error("boom")', {})
    assert run_lua('if 1 > 2 then return "a" else return "b" end', {}) == "b"
