"""Unit tests for the replica router (no model, no engine).

Every routing behavior is exercised against stub replicas that speak
``InferenceService`` over in-memory transports: circuit-breaker
transitions, consistent-hash prefix affinity, health gating, load
scoring, keyed unary failover, hedged requests (win and cancel), stream
failover from the delivered-cursor watermark, the epoch guard against
silently-restarted processes, and the Stats/Health surface.
tests/test_chaos.py runs the same router over real engine replicas.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import wire
from repro.core.rpc import (Channel, IDEMPOTENCY_KEY, DedupCache,
                            ResilientChannel, Router, RpcError, Server,
                            Status, connected_pair)
from repro.serving.router import (CircuitBreaker, ReplicaRouter,
                                  RouterConfig, build_router_server)
from repro.serving.service import (InferChunk, InferenceService,
                                   InferRequest, encode_prompt_page)

INFER = InferenceService.method("Infer").id
STREAM = InferenceService.method("InferStream").id


class StubReplica:
    """InferenceService speaker with scriptable delays, kill and restart.

    ``restart()`` bumps the epoch — the stand-in for a process coming
    back with a fresh ``time_ns`` stamp — while keeping the same dial,
    which is exactly the silent-resume hazard the epoch guard exists for.
    """

    def __init__(self, name, *, chunks=4, infer_delay=0.0, chunk_delay=0.0,
                 queue_depth=0.0):
        self.name = name
        self.chunks = chunks
        self.infer_delay = infer_delay
        self.chunk_delay = chunk_delay
        self.queue_depth = queue_depth
        self.epoch = 1
        self.draining = False
        self.infer_calls = 0
        self.stream_calls = 0
        self._dead = False
        self._open = []
        self._lock = threading.Lock()
        rt = Router()
        for mname in ("Infer", "InferStream", "Health"):
            m = InferenceService.method(mname)
            rt.register_handler(m.id, getattr(self, mname), name=m.name,
                                kind=m.kind, request_type=m.request,
                                response_type=m.response,
                                service=InferenceService.name)
        self.server = Server(rt)

    # -- handlers -------------------------------------------------------------
    def Infer(self, req, ctx):
        self.infer_calls += 1
        if self.infer_delay:
            time.sleep(self.infer_delay)
        # echo the request page with this replica's name appended, so
        # tests can see exactly which replica answered
        page = bytes(bytearray(req["page"])) + self.name.encode()
        return {"page": page, "batch": 1, "new_tokens": 0}

    def InferStream(self, req, ctx):
        self.stream_calls += 1
        start = int(ctx.cursor or 0)
        for i in range(start, self.chunks):
            if self.chunk_delay:
                time.sleep(self.chunk_delay)
            ctx.set_cursor(i + 1)
            yield {"index": i, "page": b"chunk-%d" % i, "epoch": self.epoch}

    def Health(self, req, ctx):
        out = {"serving": not self.draining, "draining": self.draining,
               "inflight": 0, "epoch": self.epoch}
        if req.get("verbose"):
            out["names"] = "queued_requests"
            out["values"] = np.asarray([self.queue_depth], np.float64)
        return out

    # -- process lifecycle ----------------------------------------------------
    def dial(self):
        with self._lock:
            if self._dead:
                raise ConnectionError(f"{self.name} is down")
            client, served = connected_pair()
            self._open.append((client, served))
        self.server.serve_transport(served, blocking=False)
        return client

    def kill(self):
        with self._lock:
            self._dead = True
            conns, self._open = self._open, []
        for pair in conns:
            for t in pair:
                try:
                    t.close()
                except Exception:  # noqa: BLE001
                    pass

    def restart(self):
        """Crash and come straight back with a new process epoch."""
        self.kill()
        self.epoch += 1
        with self._lock:
            self._dead = False


def _dial_server(server):
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    return ct


def _build(stubs, **cfg_kw):
    cfg_kw.setdefault("health_interval_s", 0)   # tests poll manually
    cfg_kw.setdefault("hedge", False)
    server, router = build_router_server(stubs, RouterConfig(**cfg_kw))
    return server, router


PROMPT = np.arange(32, dtype=np.uint32)[None, :]
REQ_RAW = wire.encode(InferRequest, {"page": encode_prompt_page(PROMPT),
                                     "max_new_tokens": 4})


# -- circuit breaker ----------------------------------------------------------

def test_breaker_opens_probes_and_recloses():
    clk = {"t": 0.0}
    b = CircuitBreaker(threshold=2, reset_after=5.0,
                       clock=lambda: clk["t"])
    assert b.ready() and b.allow()
    b.record_failure()
    assert b.state == b.CLOSED      # one failure is below threshold
    b.record_failure()
    assert b.state == b.OPEN and b.opens == 1
    assert not b.ready() and not b.allow()
    clk["t"] = 5.0                  # reset window elapsed
    assert b.ready()
    assert b.allow()                # this caller is the half-open probe
    assert b.state == b.HALF_OPEN
    assert not b.allow()            # only ONE probe is admitted
    b.record_failure()              # probe failed: straight back to open
    assert b.state == b.OPEN and b.opens == 2
    clk["t"] = 10.0
    assert b.allow()
    b.record_success()              # probe succeeded: fully closed
    assert b.state == b.CLOSED and b.failures == 0 and b.allow()


# -- affinity -----------------------------------------------------------------

def test_affinity_key_is_block_aligned_prefix():
    _, router = _build([StubReplica("a"), StubReplica("b")],
                       affinity_prefix=16, affinity_block=16)
    key = router._affinity_key(REQ_RAW)
    assert key == PROMPT[0, :16].astype("<u4").tobytes()
    # prompts sharing the first block map to the same key even when the
    # tail diverges
    other = PROMPT.copy()
    other[0, 20:] += 7
    raw2 = wire.encode(InferRequest, {"page": encode_prompt_page(other),
                                      "max_new_tokens": 4})
    assert router._affinity_key(raw2) == key
    # shorter than one block -> no affinity; malformed -> no affinity
    short = wire.encode(InferRequest, {
        "page": encode_prompt_page(PROMPT[:, :8]), "max_new_tokens": 4})
    assert router._affinity_key(short) is None
    assert router._affinity_key(b"\x00garbage") is None


def test_affinity_routing_is_sticky_with_consistent_failover():
    stubs = [StubReplica(f"s{i}") for i in range(3)]
    _, router = _build(stubs, affinity_prefix=16, affinity_block=16)
    key = router._affinity_key(REQ_RAW)
    first = router._pick(affinity=key)
    assert all(router._pick(affinity=key) is first for _ in range(10))
    # gate the owner out: the fallback is a deterministic second choice
    first.poll_ok = False
    second = router._pick(affinity=key)
    assert second is not None and second is not first
    assert all(router._pick(affinity=key) is second for _ in range(10))
    first.poll_ok = True           # owner back: affinity snaps back
    assert router._pick(affinity=key) is first
    # different keys actually spread across replicas
    owners = set()
    for seed in range(32):
        k = np.full(16, seed, np.uint32).tobytes()
        owners.add(router._pick(affinity=k).name)
    assert len(owners) > 1


# -- health gating and load ---------------------------------------------------

def test_poll_gates_out_draining_and_dead_replicas():
    stubs = [StubReplica("a"), StubReplica("b"), StubReplica("c")]
    _, router = _build(stubs)
    router.poll()
    assert all(r.routable() for r in router.replicas)
    assert router.stats["health_polls"] == 3
    stubs[1].draining = True
    stubs[2].kill()
    router.poll()
    assert router.replicas[0].routable()
    assert not router.replicas[1].routable()   # draining via Health
    assert not router.replicas[2].routable()   # dial refused
    assert router.stats["health_poll_failures"] == 1
    assert router._pick() is router.replicas[0]
    stubs[2]._dead = False                     # back up: next poll re-gates
    stubs[1].draining = False
    router.poll()
    assert all(r.routable() for r in router.replicas)


def test_pick_prefers_lowest_load():
    stubs = [StubReplica("a", queue_depth=5.0), StubReplica("b"),
             StubReplica("c", queue_depth=2.0)]
    _, router = _build(stubs)
    router.poll()                  # pulls queued_requests into the score
    assert router._pick() is router.replicas[1]
    router.replicas[1].inflight = 4   # 2x weight: now the worst choice
    assert router._pick() is router.replicas[2]


def test_poll_epoch_change_is_counted():
    stubs = [StubReplica("a")]
    _, router = _build(stubs)
    router.poll()
    assert router.replicas[0].epoch == 1
    stubs[0].restart()
    router.poll()
    assert router.replicas[0].epoch == 2
    assert router.stats["epoch_changes"] == 1


# -- unary failover -----------------------------------------------------------

def test_unary_failover_to_survivor():
    stubs = [StubReplica("a"), StubReplica("b")]
    server, router = _build(stubs)
    ch = Channel(_dial_server(server))
    inf = ch.typed(InferenceService)
    stubs[0].kill()
    stubs[1].kill()
    with pytest.raises(RpcError) as ei:   # nobody left -> UNAVAILABLE
        inf.Infer({"page": encode_prompt_page(PROMPT),
                   "max_new_tokens": 4}, timeout=10.0)
    assert ei.value.code == Status.UNAVAILABLE
    stubs[1]._dead = False                # one survivor
    res = inf.Infer({"page": encode_prompt_page(PROMPT),
                     "max_new_tokens": 4}, timeout=10.0)
    assert bytes(bytearray(res["page"])).endswith(b"b")
    assert router.stats["failovers"] >= 1
    assert stubs[0].infer_calls == 0 and stubs[1].infer_calls == 1
    ch.close()


def test_unary_failures_open_breaker():
    stubs = [StubReplica("a")]
    server, router = _build(stubs, breaker_threshold=2, breaker_reset_s=60.0)
    ch = Channel(_dial_server(server))
    inf = ch.typed(InferenceService)
    stubs[0].kill()
    for _ in range(2):
        with pytest.raises(RpcError):
            inf.Infer({"page": encode_prompt_page(PROMPT),
                       "max_new_tokens": 4}, timeout=10.0)
    r = router.replicas[0]
    assert r.breaker.state == CircuitBreaker.OPEN
    assert not r.routable()
    assert router.collect_stats()["breaker_opens"] >= 1
    # with the breaker open the router refuses instantly (no dial storm)
    with pytest.raises(RpcError) as ei:
        inf.Infer({"page": encode_prompt_page(PROMPT),
                   "max_new_tokens": 4}, timeout=10.0)
    assert ei.value.code == Status.UNAVAILABLE
    assert router.stats["no_replica_errors"] >= 1
    ch.close()


# -- hedging ------------------------------------------------------------------

def test_hedge_wins_when_primary_is_slow():
    stubs = [StubReplica("slow", infer_delay=0.6), StubReplica("fast")]
    server, router = _build(stubs, hedge=True, hedge_delay_ms=30.0,
                            affinity_prefix=0)  # load routing: slow first
    ch = Channel(_dial_server(server))
    inf = ch.typed(InferenceService)
    t0 = time.monotonic()
    res = inf.Infer({"page": encode_prompt_page(PROMPT),
                     "max_new_tokens": 4}, timeout=10.0)
    assert bytes(bytearray(res["page"])).endswith(b"fast")
    assert time.monotonic() - t0 < 0.6      # did not wait out the primary
    assert router.stats["hedges_fired"] == 1
    assert router.stats["hedges_won"] == 1
    ch.close()


def test_hedge_cancelled_when_primary_wins():
    stubs = [StubReplica("fast", infer_delay=0.15),
             StubReplica("spare", infer_delay=10.0)]
    server, router = _build(stubs, hedge=True, hedge_delay_ms=1.0,
                            affinity_prefix=0)
    ch = Channel(_dial_server(server))
    inf = ch.typed(InferenceService)
    res = inf.Infer({"page": encode_prompt_page(PROMPT),
                     "max_new_tokens": 4}, timeout=10.0)
    assert bytes(bytearray(res["page"])).endswith(b"fast")
    assert router.stats["hedges_fired"] == 1
    assert router.stats["hedges_cancelled"] == 1
    assert router.stats["hedges_won"] == 0
    ch.close()


# -- streams: watermark failover + epoch guard --------------------------------

def _collect_stream(ch, on_item=None, timeout=15.0):
    pages = []
    for item in ch.call(STREAM, REQ_RAW, server_stream=True,
                        timeout=timeout):
        chunk = wire.decode(InferChunk, item.payload)
        pages.append(bytes(bytearray(chunk["page"])))
        if on_item is not None:
            on_item(len(pages))
    return pages


def test_stream_failover_is_gap_and_duplicate_free():
    stubs = [StubReplica(f"s{i}", chunks=6, chunk_delay=0.03)
             for i in range(2)]
    server, router = _build(stubs)
    baseline = [b"chunk-%d" % i for i in range(6)]
    ch = Channel(_dial_server(server))

    def kill_owner_at_two(n):
        if n == 2:
            for stub, rep in zip(stubs, router.replicas):
                if rep.inflight:
                    stub.kill()

    got = _collect_stream(ch, on_item=kill_owner_at_two)
    assert got == baseline
    assert router.stats["stream_failovers"] >= 1
    # the survivor resumed from the watermark, not from scratch: its
    # chunks start past what the dead replica already delivered
    assert stubs[0].stream_calls + stubs[1].stream_calls >= 2
    ch.close()


def test_stream_epoch_guard_rejects_silent_resume():
    stubs = [StubReplica("only", chunks=6, chunk_delay=0.03)]
    server, router = _build(stubs)
    baseline = [b"chunk-%d" % i for i in range(6)]
    ch = Channel(_dial_server(server))

    def restart_at_two(n):
        if n == 2:
            stubs[0].restart()    # same dial, NEW epoch: the trap

    got = _collect_stream(ch, on_item=restart_at_two)
    assert got == baseline
    # the per-attempt channel silently resumed into the restarted
    # process; the guard must have rejected that delivery
    assert router.stats["epoch_rejections"] >= 1
    ch.close()


def test_client_keyed_retry_dedups_at_router():
    stubs = [StubReplica("a")]
    server, router = _build(stubs)
    ch = Channel(_dial_server(server))
    raw = REQ_RAW
    md = {IDEMPOTENCY_KEY: "logical-call-1"}
    r1 = ch.call(INFER, raw, metadata=dict(md), timeout=10.0)
    r2 = ch.call(INFER, raw, metadata=dict(md), timeout=10.0)
    assert bytes(r1) == bytes(r2)
    assert stubs[0].infer_calls == 1      # replayed, not re-executed
    assert server.dedup.hits == 1
    ch.close()


# -- stats surface ------------------------------------------------------------

def test_router_stats_and_health_rpcs():
    stubs = [StubReplica("a"), StubReplica("b")]
    server, router = _build(stubs)
    router.poll()
    ch = Channel(_dial_server(server))
    inf = ch.typed(InferenceService)
    inf.Infer({"page": encode_prompt_page(PROMPT), "max_new_tokens": 4},
              timeout=10.0)
    st = inf.Stats({})
    stats = dict(zip(st["names"].split("\n"),
                     np.asarray(st["values"], np.float64)))
    for k in ("requests", "failovers", "stream_failovers", "hedges_fired",
              "epoch_rejections", "breaker_opens", "replicas",
              "replica0_reconnects", "replica0_retries", "replica0_gaps",
              "replica1_routable", "replica1_breaker_open"):
        assert k in stats, f"missing stat {k}"
    assert stats["requests"] == 1 and stats["replicas"] == 2
    h = inf.Health({"verbose": True})
    assert h["serving"] and not h["draining"]
    assert h["epoch"] == router.epoch
    assert "requests" in h["names"].split("\n")
    # every replica gone -> the router reports itself unserving
    for s in stubs:
        s.kill()
    router.poll()
    h2 = inf.Health({})
    assert not h2["serving"]
    ch.close()


def test_resilient_channel_collect_stats_counts_reconnects():
    stub = StubReplica("a")
    rc = ResilientChannel(stub.dial)
    assert rc.collect_stats() == {"reconnects": 0, "retries": 0, "gaps": 0}
    ch_before = rc.collect_stats()
    # sever the live connection; the next call must reconnect
    md = {IDEMPOTENCY_KEY: "k1"}
    rc.call(INFER, REQ_RAW, metadata=md, timeout=10.0)
    stub.kill()
    stub._dead = False
    rc.call(INFER, REQ_RAW, metadata={IDEMPOTENCY_KEY: "k2"}, timeout=10.0)
    after = rc.collect_stats()
    assert after["reconnects"] > ch_before["reconnects"]
    rc.close()


def test_dedup_cache_counts_evictions():
    d = DedupCache(max_entries=2)
    for i in range(4):
        kind, e = d.begin(f"k{i}")
        assert kind == "mine"
        d.finish(e, b"resp", 0, None)
    assert d.evictions == 2
    assert d.hits == 0
