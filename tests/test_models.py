"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values; decode paths for every family.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import get_model


def _batch_for(cfg, b, t, rng):
    if cfg.input_kind == "embeddings":
        return {"embeds": rng.standard_normal((b, t, cfg.d_model))
                .astype(np.float32),
                "positions": np.broadcast_to(np.arange(t), (3, b, t))
                .astype(np.int32),
                "labels": rng.integers(0, cfg.vocab_size, (b, t))
                .astype(np.int32)}
    if cfg.input_kind == "frames":
        return {"frames": rng.standard_normal(
            (b, max(t // cfg.frame_ratio, 1), cfg.d_model))
            .astype(np.float32),
            "tokens": rng.integers(0, cfg.vocab_size, (b, t))
            .astype(np.int32),
            "labels": rng.integers(0, cfg.vocab_size, (b, t))
            .astype(np.int32)}
    return {"tokens": rng.integers(0, cfg.vocab_size, (b, t))
            .astype(np.int32),
            "labels": rng.integers(0, cfg.vocab_size, (b, t))
            .astype(np.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch, rng):
    cfg = reduced_config(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, t = 2, 16
    batch = _batch_for(cfg, b, t, rng)
    logits = jax.jit(model.logits)(params, batch)
    assert logits.shape == (b, t, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gn = jax.tree.reduce(
        lambda a, bb: a + bb,
        jax.tree.map(lambda g: float(jnp.sum(jnp.abs(
            g.astype(jnp.float32)))), grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).input_kind != "embeddings"])
def test_smoke_decode_path(arch, rng):
    """prefill + N decode steps; cache shapes stable, logits finite."""
    cfg = reduced_config(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, t, cache_len = 2, 8, 32
    batch = _batch_for(cfg, b, t, rng)
    batch.pop("labels")
    logits, cache = jax.jit(
        lambda p, bt: model.prefill(p, bt, cache_len))(params, batch)
    assert logits.shape == (b, cfg.vocab_size)
    shapes0 = jax.tree.map(lambda a: a.shape, cache)
    tok = rng.integers(0, cfg.vocab_size, (b, 1)).astype(np.int32)
    step = jax.jit(model.decode_step)
    for i in range(3):
        logits, cache = step(params, tok, cache, jnp.int32(t + i))
        assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.map(lambda a: a.shape, cache) == shapes0


def test_prefill_decode_consistency(rng):
    """Greedy next-token from (prefill then decode) == full forward argmax."""
    cfg = reduced_config(get_config("qwen2-1.5b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, t = 1, 12
    toks = rng.integers(0, cfg.vocab_size, (b, t + 1)).astype(np.int32)
    # full forward logits at position t-1 predict token t
    full = model.logits(params, {"tokens": toks[:, :t]})
    logits_prefill, cache = model.prefill(params, {"tokens": toks[:, :t]},
                                          cache_len=32)
    np.testing.assert_allclose(np.asarray(full[:, -1]),
                               np.asarray(logits_prefill), atol=2e-2,
                               rtol=2e-2)
    # decode one more token and compare with forward over t+1
    full2 = model.logits(params, {"tokens": toks})
    logits_dec, _ = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
    np.testing.assert_allclose(np.asarray(full2[:, -1]),
                               np.asarray(logits_dec), atol=2e-2, rtol=2e-2)


def test_paged_step_verify_matches_sequential_steps(rng):
    """The speculative verifier's per-position logits == what sequential
    one-token paged steps produce at the same positions (same pool
    content, same masks) — the property that makes draft acceptance
    equivalent to running the serial loop."""
    from repro.serving import PagedKVCache

    cfg = reduced_config(get_config("qwen2-1.5b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    t = 6
    toks = rng.integers(0, cfg.vocab_size, (1, t)).astype(np.int32)

    def fresh():
        cache = PagedKVCache(num_layers=cfg.num_layers,
                             num_kv_heads=cfg.num_kv_heads,
                             head_dim=cfg.head_dim, cache_len=64,
                             block_size=16, max_concurrent=1,
                             dtype=cfg.dtype, prefix_cache=False)
        cache.pool = model.init_paged_pool(cache.layout.num_blocks,
                                           cache.block_size)
        table = jnp.asarray(cache.allocate(0, 64)[None, :])
        return cache, table

    # sequential: t one-token steps, logits after consuming tokens 0..j
    cache, table = fresh()
    seq_logits = []
    for j in range(t):
        logits, cache.pool = model.paged_step(
            params, jnp.asarray(toks[:, j:j + 1]), cache.pool, table,
            jnp.full((1, 1), j, jnp.int32), jnp.zeros((1,), jnp.int32))
        seq_logits.append(np.asarray(logits))
    # verify: ONE call over all t tokens, logits at every position
    cache, table = fresh()
    ver_logits, _ = model.paged_step_verify(
        params, jnp.asarray(toks), cache.pool, table,
        jnp.arange(t, dtype=jnp.int32)[None, :],
        jnp.full((1,), t - 1, jnp.int32))
    ver_logits = np.asarray(ver_logits)
    assert ver_logits.shape == (1, t, cfg.vocab_size)
    for j in range(t):
        np.testing.assert_allclose(ver_logits[:, j], seq_logits[j],
                                   atol=1e-4, rtol=1e-4)
        assert ver_logits[:, j].argmax(-1) == seq_logits[j].argmax(-1)


def test_rwkv_decode_matches_forward(rng):
    """RWKV state decode == full-sequence forward (stronger check: exact
    recurrence)."""
    cfg = reduced_config(get_config("rwkv6-7b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    b, t = 1, 10
    toks = rng.integers(0, cfg.vocab_size, (b, t + 1)).astype(np.int32)
    full = model.logits(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :t]}, cache_len=0)
    logits_dec, _ = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
    np.testing.assert_allclose(np.asarray(full[:, -1]),
                               np.asarray(logits_dec), atol=2e-2, rtol=2e-2)


def test_local_window_attention_masks(rng):
    """recurrentgemma window: token t must not see tokens < t-window+1."""
    from repro.kernels import ref
    q = rng.standard_normal((1, 1, 8, 4)).astype(np.float32)
    k = rng.standard_normal((1, 1, 8, 4)).astype(np.float32)
    v = np.eye(8, 4, dtype=np.float32)[None, None]
    out_w2 = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           causal=True, window=2)
    # with window=2, position 7 attends only to {6, 7}: rows of v beyond
    # are unreachable
    probsless = np.asarray(out_w2)[0, 0, 7]
    full = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=True)
    assert not np.allclose(probsless, np.asarray(full)[0, 0, 7])


def test_moe_aux_loss_and_flops_scaling(rng):
    cfg = reduced_config(get_config("qwen2-moe-a2.7b"))
    from repro.models.moe import init_moe, moe_ffn
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)),
                    dtype=jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) > 0
    assert np.isfinite(np.asarray(out)).all()


def test_moe_capacity_drops_dont_nan(rng):
    """Tiny capacity factor forces drops; output must stay finite."""
    import dataclasses
    cfg = reduced_config(get_config("granite-moe-1b-a400m"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    from repro.models.moe import init_moe, moe_ffn
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)),
                    dtype=jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()


def test_mrope_sections_rotate_by_different_axes(rng):
    from repro.models.layers import apply_mrope
    x = rng.standard_normal((1, 1, 4, 16)).astype(np.float32)
    # positions differ per axis
    p3 = np.stack([np.zeros((1, 4)), np.arange(4)[None],
                   2 * np.arange(4)[None]]).astype(np.int32)
    out = apply_mrope(jnp.asarray(x), jnp.asarray(p3), 10000.0, (2, 3, 3))
    assert out.shape == x.shape
    # t-axis positions all zero -> first section unrotated
    np.testing.assert_allclose(np.asarray(out)[..., :2], x[..., :2],
                               atol=1e-5)
    assert not np.allclose(np.asarray(out)[..., 2:8], x[..., 2:8])


def test_param_counts_match_published():
    expected = {"gemma-2b": (2.0, 3.0), "qwen2-1.5b": (1.2, 1.9),
                "yi-34b": (32, 36), "qwen2-72b": (70, 76),
                "rwkv6-7b": (6.5, 8.5), "recurrentgemma-9b": (7.5, 10.5),
                "qwen2-moe-a2.7b": (13, 15.5),
                "granite-moe-1b-a400m": (1.0, 1.7),
                "qwen2-vl-2b": (1.2, 1.9),
                "seamless-m4t-medium": (0.7, 1.6)}
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, (arch, n)
    # MoE active params
    assert 2.2 <= get_config("qwen2-moe-a2.7b").active_param_count() / 1e9 <= 3.2
    assert 0.3 <= get_config("granite-moe-1b-a400m").active_param_count() / 1e9 <= 0.6
