"""Serving integration: the paper's RPC protocol carrying a real model."""
import numpy as np
import pytest
import uuid

from repro.configs import get_config, reduced_config
from repro.core import wire
from repro.core.rpc import Channel, Deadline, RpcError, Status, connected_pair
from repro.serving import (ContinuousBatcher, Engine, PagedBatcher,
                           ServeConfig, ShedError, build_server)
from repro.serving.service import (GenerateRequest, GenerateResponse,
                                   InferenceService, ScoreResponse,
                                   TokenChunk, TokenizeRequest)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen2-1.5b"))
    engine = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=8))
    server = build_server(engine)
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    yield cfg, engine, ch
    ch.close()


def _prompt(cfg, b=1, t=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (b, t)).astype(np.uint32)


def test_generate_unary(setup):
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    p = _prompt(cfg)
    res = inf.Generate({"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                        "max_new_tokens": 4})
    assert res["new_tokens"] == 4
    assert len(res["tokens"]) == 4


def test_generate_deterministic(setup):
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    p = _prompt(cfg)
    req = {"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
           "max_new_tokens": 4}
    a = list(inf.Generate(dict(req))["tokens"])
    b = list(inf.Generate(dict(req))["tokens"])
    assert a == b  # greedy decoding is deterministic


def test_stream_with_cursor_resume(setup):
    """Drop after 3 tokens; resume with cursor; identical total stream."""
    cfg, engine, ch = setup
    did = InferenceService.method("Stream").id
    p = _prompt(cfg)
    req = wire.encode(GenerateRequest,
                      {"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                       "max_new_tokens": 6})
    it = ch.call(did, req, server_stream=True)
    got, cursor = [], 0
    for item in it:
        chunk = wire.decode(TokenChunk, item.payload)
        got.extend(chunk["tokens"])
        cursor = item.cursor
        if chunk["index"] == 2:
            break
    it2 = ch.call(did, req, server_stream=True, cursor=cursor)
    rest = []
    for item in it2:
        rest.extend(wire.decode(TokenChunk, item.payload)["tokens"])
    full = [int(x) for x in got + rest]
    # reference: one-shot generate
    inf = ch.typed(InferenceService)
    ref = [int(x) for x in inf.Generate(
        {"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
         "max_new_tokens": 6})["tokens"]]
    assert full == ref


def test_batch_pipeline_tokenize_generate_score(setup):
    """The §7.3 flow on a real model: 3 dependent calls, 1 round trip."""
    cfg, engine, ch = setup
    tid = InferenceService.method("Tokenize").id
    gid = InferenceService.method("Generate").id
    sid = InferenceService.method("Score").id
    res = ch.batch([
        {"method_id": tid, "payload": wire.encode(
            TokenizeRequest, {"text": "hello bebop", "seq_len": 8})},
        # TokenBatch and GenerateRequest share tags 1-3, so the forwarded
        # result decodes as a valid GenerateRequest (schema-compatible
        # pipelining, like the paper's user->friends example)
        {"method_id": gid, "input_from": 0},
        {"method_id": sid, "input_from": 1},
    ])
    assert [r["status"] for r in res] == [Status.OK] * 3
    gen = wire.decode(GenerateResponse, res[1]["payload"])
    assert gen["new_tokens"] >= 1
    score = wire.decode(ScoreResponse, res[2]["payload"])
    assert len(score["scores"]) == 1
    assert np.isfinite(score["scores"][0])


def test_generate_deadline_shedding(setup):
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    p = _prompt(cfg)
    with pytest.raises(RpcError) as ei:
        inf.Generate({"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                      "max_new_tokens": 4}, deadline=Deadline.after(-1))
    assert ei.value.code == Status.DEADLINE_EXCEEDED


def test_long_generation_as_future(setup):
    cfg, engine, ch = setup
    gid = InferenceService.method("Generate").id
    p = _prompt(cfg)
    req = wire.encode(GenerateRequest,
                      {"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                       "max_new_tokens": 6})
    key = uuid.uuid4()
    h = ch.dispatch_future(gid, req, idempotency_key=key)
    results = list(ch.resolve_futures([h["id"]]))
    assert results[0]["status"] == Status.OK
    out = wire.decode(GenerateResponse, results[0]["payload"])
    assert out["new_tokens"] == 6
    # retried dispatch with same key: same handle
    h2 = ch.dispatch_future(gid, req, idempotency_key=key)
    assert h2["id"] == h["id"]


# -- paged scheduler: mixed-length batching --------------------------------

@pytest.fixture(scope="module")
def paged(setup):
    cfg, engine, _ = setup
    batcher = PagedBatcher(engine, max_batch=8)
    yield cfg, engine, batcher
    batcher.close()


def test_paged_mixed_lengths_token_identical(paged):
    """A heterogeneous batch must produce exactly what each request gets
    when it runs alone — the acceptance invariant for the paged cache."""
    cfg, engine, batcher = paged
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab_size, (1, t)).astype(np.int32)
               for t in (5, 8, 11, 16, 3, 9, 24, 7)]
    futs = [batcher.submit(p, max_new_tokens=6) for p in prompts]
    outs = [f.result(timeout=180) for f in futs]
    assert all(o.shape == (1, 6) for o in outs)
    # decode steps really were shared across mixed lengths
    assert batcher.mean_batch_rows() > 1.0
    for p, o in zip(prompts, outs):
        solo = batcher.generate(p, max_new_tokens=6)
        assert np.array_equal(o, solo)


def test_paged_matches_dense_engine(paged):
    """Paged and dense caches hold the same K/V; greedy tokens agree."""
    cfg, engine, batcher = paged
    rng = np.random.default_rng(7)
    for t in (4, 13, 21):
        p = rng.integers(0, cfg.vocab_size, (1, t)).astype(np.int32)
        assert np.array_equal(batcher.generate(p, max_new_tokens=5),
                              engine.generate(p, max_new_tokens=5))


def test_paged_stop_token_invariance_heterogeneous(paged):
    """Stop-token semantics are per-request even in a mixed-length batch:
    being batched with strangers never changes where a response ends."""
    cfg, engine, batcher = paged
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, (1, t)).astype(np.int32)
               for t in (6, 9, 14, 5)]
    # solo references first (each alone in the batcher)
    solos = [batcher.generate(p, max_new_tokens=8, stop_token=int(s))
             for p, s in zip(prompts, (1, 2, 3, 4))]
    futs = [batcher.submit(p, max_new_tokens=8, stop_token=int(s))
            for p, s in zip(prompts, (1, 2, 3, 4))]
    for f, solo in zip(futs, solos):
        assert np.array_equal(f.result(timeout=180), solo)


def test_paged_multirow_request(paged):
    """[B, T] prompts occupy B slots and stay row-consistent."""
    cfg, engine, batcher = paged
    rng = np.random.default_rng(11)
    p = rng.integers(0, cfg.vocab_size, (2, 10)).astype(np.int32)
    out = batcher.generate(p, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert np.array_equal(out, engine.generate(p, max_new_tokens=4))


def test_paged_prefill_only_and_deadline_shed(paged):
    cfg, engine, batcher = paged
    rng = np.random.default_rng(13)
    p = rng.integers(0, cfg.vocab_size, (1, 9)).astype(np.int32)
    assert batcher.generate(p, max_new_tokens=0).shape == (1, 0)
    fut = batcher.submit(p, max_new_tokens=4, deadline=Deadline.after(-1))
    with pytest.raises(ShedError):
        fut.result(timeout=30)


def test_paged_budget_overflow_falls_back_dense(paged):
    """A request whose prompt + generation overruns the block table must
    not clamp-corrupt the cache — it takes the dense path and matches the
    dense engine exactly."""
    cfg, engine, batcher = paged
    rng = np.random.default_rng(23)
    # cache_len is 64: 60 + 8 > 64 can never fit the paged budget
    p = rng.integers(0, cfg.vocab_size, (1, 60)).astype(np.int32)
    before = batcher.stats["dense_fallbacks"]
    out = batcher.generate(p, max_new_tokens=8)
    assert batcher.stats["dense_fallbacks"] == before + 1
    assert np.array_equal(out, engine.generate(p, max_new_tokens=8))
    # pool untouched: everything free or idle-cached (reclaimable blocks
    # are prefix-cache residue from earlier tests in this module)
    assert batcher.cache.num_free_blocks + batcher.cache.reclaimable \
        == batcher.cache.allocator.capacity


def test_paged_blocks_are_returned(paged):
    """After a workload drains, every block is back in the pool (free or
    idle-cached) — including those of shed requests."""
    cfg, engine, batcher = paged
    rng = np.random.default_rng(17)
    futs = [batcher.submit(
        rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32),
        max_new_tokens=3) for _ in range(6)]
    futs.append(batcher.submit(
        rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32),
        max_new_tokens=3, deadline=Deadline.after(-1)))
    for f in futs[:-1]:
        f.result(timeout=180)
    with pytest.raises(ShedError):
        futs[-1].result(timeout=30)
    assert batcher.cache.num_free_blocks + batcher.cache.reclaimable \
        == batcher.cache.allocator.capacity


# -- fused prefill/decode scheduling ---------------------------------------

class _FlipDeadline:
    """Deterministic deadline: live for the first N expiry checks, then
    expired — lands the expiry mid-prefill without wall-clock races."""

    def __init__(self, live_checks: int):
        self.remaining = live_checks

    def expired(self) -> bool:
        self.remaining -= 1
        return self.remaining < 0


@pytest.fixture(scope="module")
def fused(setup):
    """Small chunks + a step-token budget so a 40-token prompt takes many
    fused steps — plenty of room to observe interleaving.  Prefix caching
    is OFF: these tests count prefill chunks, and a cache hit would
    (correctly) skip the very chunks they assert on."""
    cfg, engine, _ = setup
    eng = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=8,
                                  prefill_chunk=4, max_step_tokens=5,
                                  prefix_cache=False),
                 params=engine.params)
    batcher = PagedBatcher(eng, max_batch=6)
    yield cfg, eng, batcher
    batcher.close()


def test_fused_decodes_advance_during_prefill(fused):
    """The tentpole invariant: in-flight decodes receive tokens WHILE a
    long prompt prefills, and everyone's tokens match their solo run."""
    cfg, engine, batcher = fused
    rng = np.random.default_rng(31)
    dec_prompts = [rng.integers(0, cfg.vocab_size, (1, t)).astype(np.int32)
                   for t in (5, 9)]
    long_prompt = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
    solos = [batcher.generate(p, max_new_tokens=8) for p in dec_prompts]
    solo_long = batcher.generate(long_prompt, max_new_tokens=8)

    stamps = [[] for _ in dec_prompts]
    futs = [batcher.submit(
        p, max_new_tokens=8,
        on_token=lambda idx, tok, i=i: stamps[i].append(
            batcher.stats["prefill_chunks"]))
        for i, p in enumerate(dec_prompts)]
    # make sure the decodes are in flight before the long prompt arrives
    import time as _time
    t0 = _time.monotonic()
    while min(len(s) for s in stamps) < 2:
        assert _time.monotonic() - t0 < 120, "decodes never started"
        _time.sleep(0.001)
    pc_admit = batcher.stats["prefill_chunks"]
    f_long = batcher.submit(long_prompt, max_new_tokens=8)
    outs = [f.result(timeout=180) for f in futs]
    out_long = f_long.result(timeout=180)
    pc_done = batcher.stats["prefill_chunks"]
    for solo, out in zip(solos, outs):
        assert np.array_equal(solo, out)
    assert np.array_equal(solo_long, out_long)
    # each stamp records the prefill-chunk counter at token emission: a
    # stamp strictly inside (pc_admit, pc_done) is a decode token that
    # arrived while the long prompt's chunks were still being ingested —
    # the blocking scheduler can never produce one
    assert pc_done - pc_admit >= 40 // 4, "long prefill too few chunks"
    mid = [s for ts in stamps for s in ts if pc_admit < s < pc_done]
    assert mid, "no decode token emitted during the long prompt's prefill"
    assert batcher.stats["mixed_steps"] > 0


def test_fused_admission_during_anothers_prefill(fused):
    """A request admitted while another's prefill is mid-flight: both
    prefills interleave through fused steps and both match solo runs."""
    cfg, engine, batcher = fused
    rng = np.random.default_rng(37)
    pa = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, (1, 36)).astype(np.int32)
    solo_a = batcher.generate(pa, max_new_tokens=6)
    solo_b = batcher.generate(pb, max_new_tokens=6)
    before = batcher.stats["admitted_in_flight"]
    fa = batcher.submit(pa, max_new_tokens=6)
    fb = batcher.submit(pb, max_new_tokens=6)
    assert np.array_equal(fa.result(timeout=180), solo_a)
    assert np.array_equal(fb.result(timeout=180), solo_b)
    assert batcher.stats["admitted_in_flight"] >= before


def test_fused_deadline_mid_prefill_returns_blocks(fused):
    """Expiry mid-prefill delivers the empty prefix and returns every
    block to the pool — the shed contract holds inside a fused prefill."""
    cfg, engine, batcher = fused
    rng = np.random.default_rng(41)
    p = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
    free_before = batcher.cache.num_free_blocks
    out = batcher.submit(p, max_new_tokens=8,
                         deadline=_FlipDeadline(4)).result(timeout=180)
    assert out.shape == (1, 0)   # admitted, expired before any token
    assert batcher.cache.num_free_blocks == free_before


def test_fused_max_step_tokens_budget(fused):
    """With max_step_tokens=5 and chunk 4, prefills advance in partial
    chunks whenever decode rows eat into the budget, and a lone prefill
    still completes (budget floor is 1 token/step) — always solo-equal."""
    cfg, engine, batcher = fused
    rng = np.random.default_rng(43)
    p = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
    a = batcher.generate(p, max_new_tokens=4)
    b = batcher.generate(p, max_new_tokens=4)
    assert np.array_equal(a, b)
    assert a.shape == (1, 4)


def test_empty_prompt_shed_without_poisoning_batch(fused):
    """A 0-token prompt is rejected at submit; concurrent requests keep
    generating (the old blocking path failed it solo, the fused shared
    step must never let it fail the whole batch)."""
    cfg, engine, batcher = fused
    rng = np.random.default_rng(53)
    p = rng.integers(0, cfg.vocab_size, (1, 6)).astype(np.int32)
    solo = batcher.generate(p, max_new_tokens=5)
    good = batcher.submit(p, max_new_tokens=5)
    bad = batcher.submit(np.zeros((1, 0), np.int32), max_new_tokens=5)
    with pytest.raises(ShedError, match="empty prompt"):
        bad.result(timeout=60)
    assert np.array_equal(good.result(timeout=180), solo)


def test_on_token_exception_never_desyncs_tokens(fused):
    """A raising on_token hook must not skip the scheduler's state
    advance (which would re-feed and duplicate the token)."""
    cfg, engine, batcher = fused
    rng = np.random.default_rng(47)
    p = rng.integers(0, cfg.vocab_size, (1, 7)).astype(np.int32)
    solo = batcher.generate(p, max_new_tokens=6)

    def _bad_hook(idx, tok):
        raise RuntimeError("streaming hook exploded")
    out = batcher.submit(p, max_new_tokens=6,
                         on_token=_bad_hook).result(timeout=180)
    assert np.array_equal(out, solo)


def test_worker_errors_counted_not_swallowed(setup):
    """A step exception fails the in-flight requests AND is visible in
    stats['worker_errors'] instead of being silently retried forever."""
    cfg, engine, _ = setup
    eng = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=4),
                 params=engine.params)
    batcher = PagedBatcher(eng, max_batch=2)
    try:
        def _boom(*a, **kw):
            raise RuntimeError("injected step failure")
        batcher._step_fn = _boom
        p = np.zeros((1, 4), np.int32)
        fut = batcher.submit(p, max_new_tokens=2)
        with pytest.raises(RuntimeError, match="injected step failure"):
            fut.result(timeout=60)
        import time as _time
        t0 = _time.monotonic()
        while batcher.stats["worker_errors"] == 0:
            assert _time.monotonic() - t0 < 60
            _time.sleep(0.001)
        assert batcher.stats["worker_errors"] >= 1
        # pool is clean: the failed request's blocks came back
        assert batcher.cache.num_free_blocks == \
            batcher.cache.allocator.capacity
    finally:
        batcher.close()


class _CountedDeadline:
    """Deterministic mid-flight deadline: live for the first N expiry
    checks, expired afterwards — no wall-clock races."""

    def __init__(self, live_checks: int):
        self.remaining = live_checks

    def expired(self) -> bool:
        self.remaining -= 1
        return self.remaining < 0

    def cutoff_ns(self) -> int:
        return 10 ** 18  # ordering key only; far future


def test_dense_mixed_deadline_group_still_sheds(setup):
    """Regression: a no-deadline request used to disable mid-flight
    shedding for every deadline-bearing request batched with it (the
    group deadline was only propagated when ALL members had one)."""
    cfg, engine, _ = setup
    batcher = ContinuousBatcher(engine, max_batch=4, window_s=0.25)
    try:
        p1 = _prompt(cfg, t=8, seed=2).astype(np.int32)
        p2 = _prompt(cfg, t=8, seed=3).astype(np.int32)
        f1 = batcher.submit(p1, max_new_tokens=16)   # no deadline
        f2 = batcher.submit(p2, max_new_tokens=16,
                            deadline=_CountedDeadline(6))
        out2 = f2.result(timeout=180)
        f1.result(timeout=180)
        assert batcher.stats["batches"] == 1         # they really merged
        assert out2.shape[1] < 16   # deadline cut the generation short
    finally:
        batcher.close()


# -- prefix caching: refcounted copy-on-write KV block sharing --------------

@pytest.fixture(scope="module")
def prefixed(setup):
    """Block size 16 on a 64-token cache: prompts below 16 tokens never
    populate the index, so each test controls its own hits."""
    cfg, engine, _ = setup
    eng = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=8,
                                  prefill_chunk=8), params=engine.params)
    batcher = PagedBatcher(eng, max_batch=4)
    yield cfg, eng, batcher
    batcher.close()


def test_prefix_hit_token_identical(prefixed):
    """The acceptance invariant: a cache-hit generation is byte-for-byte
    the cold-path (and dense-engine) generation."""
    cfg, engine, batcher = prefixed
    rng = np.random.default_rng(71)
    p = rng.integers(0, cfg.vocab_size, (1, 37)).astype(np.int32)
    ref = engine.generate(p, max_new_tokens=6)
    cold = batcher.generate(p, max_new_tokens=6)
    reused0 = batcher.stats["prefix_tokens_reused"]
    assert np.array_equal(cold, ref)
    warm = batcher.generate(p, max_new_tokens=6)
    assert np.array_equal(warm, ref)
    # 37 tokens = 2 full blocks: the hit skipped exactly their prefill
    assert batcher.stats["prefix_tokens_reused"] - reused0 == 32
    assert batcher.stats["prefix_hits"] >= 1


def test_prefix_partial_hit_with_different_tail(prefixed):
    """Only the common full-block prefix is shared; a divergent tail
    must neither corrupt the donor nor change either output."""
    cfg, engine, batcher = prefixed
    rng = np.random.default_rng(73)
    head = rng.integers(0, cfg.vocab_size, (1, 32)).astype(np.int32)
    a = np.concatenate([head, rng.integers(0, cfg.vocab_size, (1, 9))
                        .astype(np.int32)], axis=1)
    b = np.concatenate([head, rng.integers(0, cfg.vocab_size, (1, 13))
                        .astype(np.int32)], axis=1)
    ref_a, ref_b = (engine.generate(x, max_new_tokens=6) for x in (a, b))
    out_a = batcher.generate(a, max_new_tokens=6)
    reused0 = batcher.stats["prefix_tokens_reused"]
    out_b = batcher.generate(b, max_new_tokens=6)
    assert np.array_equal(out_a, ref_a)
    assert np.array_equal(out_b, ref_b)
    assert batcher.stats["prefix_tokens_reused"] - reused0 == 32
    # the donor's result is reproducible after the second request wrote
    # its own divergent tail (shared blocks were never mutated)
    assert np.array_equal(batcher.generate(a, max_new_tokens=6), ref_a)


def test_prefix_block_aligned_prompt_copy_on_write(prefixed):
    """A fully-matched, block-aligned prompt re-processes its final
    token; that write lands in a shared block and must copy-on-write a
    private replacement, not mutate the cached original."""
    cfg, engine, batcher = prefixed
    rng = np.random.default_rng(79)
    p = rng.integers(0, cfg.vocab_size, (1, 32)).astype(np.int32)
    ref = engine.generate(p, max_new_tokens=6)
    assert np.array_equal(batcher.generate(p, max_new_tokens=6), ref)
    cow0 = batcher.stats["cow_copies"]
    assert np.array_equal(batcher.generate(p, max_new_tokens=6), ref)
    assert batcher.stats["cow_copies"] == cow0 + 1
    # and the cached copy is still intact for a third pass
    assert np.array_equal(batcher.generate(p, max_new_tokens=6), ref)


def test_prefix_concurrent_identical_prompts(prefixed):
    """Requests sharing a prompt admitted together: later ones may share
    blocks the first registered mid-flight; everyone's output matches."""
    cfg, engine, batcher = prefixed
    rng = np.random.default_rng(83)
    p = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
    ref = engine.generate(p, max_new_tokens=5)
    futs = [batcher.submit(p, max_new_tokens=5) for _ in range(3)]
    for f in futs:
        assert np.array_equal(f.result(timeout=180), ref)
    # all blocks accounted for: free or idle-cached, none leaked
    assert batcher.cache.num_free_blocks + batcher.cache.reclaimable \
        == batcher.cache.allocator.capacity


def test_prefix_cache_disabled_no_sharing(setup):
    cfg, engine, _ = setup
    eng = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=8,
                                  prefix_cache=False),
                 params=engine.params)
    batcher = PagedBatcher(eng, max_batch=2)
    try:
        rng = np.random.default_rng(89)
        p = rng.integers(0, cfg.vocab_size, (1, 36)).astype(np.int32)
        ref = engine.generate(p, max_new_tokens=5)
        for _ in range(2):
            assert np.array_equal(batcher.generate(p, max_new_tokens=5), ref)
        assert batcher.stats["prefix_hits"] == 0
        assert batcher.stats["prefix_tokens_reused"] == 0
        assert batcher.cache.reclaimable == 0
        assert batcher.cache.num_free_blocks \
            == batcher.cache.allocator.capacity
    finally:
        batcher.close()


def test_prefix_lru_eviction_under_pool_pressure(setup):
    """A pool too small to hold cached prefixes AND a new request evicts
    idle cache entries instead of shedding the request."""
    cfg, engine, _ = setup
    # capacity 4: a 40-token + 4-new request needs 3 blocks; after the
    # first leaves its 2 prefix blocks idle-cached only 2 are free, so
    # admitting the second must evict rather than shed
    eng = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=8,
                                  num_blocks=5), params=engine.params)
    batcher = PagedBatcher(eng, max_batch=2)
    try:
        rng = np.random.default_rng(97)
        pa = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
        pb = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
        ref_b = engine.generate(pb, max_new_tokens=4)
        batcher.generate(pa, max_new_tokens=4)      # caches pa's 2 blocks
        assert batcher.cache.reclaimable == 2
        # pool: 7 usable, 2 idle-cached; pb needs 3 -> must evict
        out_b = batcher.generate(pb, max_new_tokens=4)
        assert np.array_equal(out_b, ref_b)
        assert batcher.cache.prefix.evictions >= 1
    finally:
        batcher.close()


# -- speculative decoding: n-gram draft + fused multi-token verify ----------

def _repetitive_prompt(cfg, seed, motif_t=6, reps=4):
    motif = np.random.default_rng(seed) \
        .integers(0, cfg.vocab_size, motif_t).astype(np.int32)
    return np.tile(motif, reps)[None, :]


@pytest.fixture(scope="module")
def spec(setup):
    """Room for long decodes (cache_len 160) so accepted runs can span
    many tokens; spec decode on with the default drafter knobs."""
    cfg, engine, _ = setup
    eng = Engine(cfg, ServeConfig(cache_len=160, max_new_tokens=32),
                 params=engine.params)
    batcher = PagedBatcher(eng, max_batch=4)
    yield cfg, eng, batcher
    batcher.close()


def test_spec_decode_token_identical_with_acceptance(spec):
    """The acceptance invariant: speculative decode is a restructuring of
    the serial loop — identical tokens, several committed per step."""
    cfg, engine, batcher = spec
    outs = []
    for seed in range(3):
        p = _repetitive_prompt(cfg, seed)
        ref = engine.generate(p, max_new_tokens=24)   # dense engine oracle
        out = batcher.generate(p, max_new_tokens=24)
        assert np.array_equal(out, ref)
        outs.append(out)
    # greedy decode on repetitive prompts cycles, so drafts MUST land:
    # this asserts the speculative path really engaged, not just fell
    # back to 1-token steps forever
    assert batcher.stats["spec_steps"] > 0
    assert batcher.stats["spec_accepted"] > 0
    assert batcher.stats["spec_proposed"] >= batcher.stats["spec_accepted"]
    # and acceptance really compressed steps: fewer decode steps than
    # emitted tokens for at least one request's worth of traffic
    total = sum(o.shape[1] for o in outs)
    assert batcher.stats["decode_steps"] < total


def test_spec_stop_token_anywhere_matches_nonspec(spec):
    """Stop-token semantics survive variable advance: wherever the stop
    lands — including mid-accepted-draft — the output equals the
    non-speculative scheduler's run with the same stop."""
    cfg, engine, batcher = spec
    plain = PagedBatcher(
        Engine(cfg, ServeConfig(cache_len=160, max_new_tokens=32,
                                spec_decode=False), params=engine.params),
        max_batch=4)
    try:
        p = _repetitive_prompt(cfg, seed=7)
        ref = engine.generate(p, max_new_tokens=24)
        accepted0 = batcher.stats["spec_accepted"]
        # every emitted token doubles as a stop candidate: cycling output
        # guarantees several of them land inside an accepted run
        stops = sorted(set(int(t) for t in ref[0]))
        assert len(stops) >= 2
        for s in stops:
            want = plain.generate(p, max_new_tokens=24, stop_token=s)
            got = batcher.generate(p, max_new_tokens=24, stop_token=s)
            assert np.array_equal(got, want), f"stop_token={s}"
            assert not (got == s).all(axis=0).any()  # stop never emitted
        assert batcher.stats["spec_accepted"] > accepted0
    finally:
        plain.close()


def test_spec_max_new_tokens_inside_accepted_run(spec):
    """max_new_tokens landing inside an accepted draft run truncates to
    exactly the budget — never a token more, always the same tokens."""
    cfg, engine, batcher = spec
    p = _repetitive_prompt(cfg, seed=11)
    full = engine.generate(p, max_new_tokens=24)
    for maxn in (1, 2, 3, 5, 8, 13, 24):
        out = batcher.generate(p, max_new_tokens=maxn)
        assert out.shape == (1, maxn)
        assert np.array_equal(out, full[:, :maxn])


def test_spec_multirow_lockstep(spec):
    """[B, T] rows advance in lockstep: the accepted run is the prefix
    EVERY row verifies, and outputs match the dense engine's."""
    cfg, engine, batcher = spec
    p = np.concatenate([_repetitive_prompt(cfg, 13),
                        _repetitive_prompt(cfg, 17)], axis=0)
    ref = engine.generate(p, max_new_tokens=16)
    out = batcher.generate(p, max_new_tokens=16)
    assert out.shape == (2, 16)
    assert np.array_equal(out, ref)


def test_spec_deadline_shed_between_draft_and_verify(spec):
    """Expiry during the draft/verify window delivers the generated
    prefix and returns every block — for ANY point the deadline lands,
    including the host-side drafting gap between two device steps."""
    cfg, engine, batcher = spec
    p = _repetitive_prompt(cfg, seed=19)
    ref = engine.generate(p, max_new_tokens=24)
    # expiry checks alternate scheduler sites (step prologue, post-draft
    # shed point, ...): sweeping the flip count lands shed on all of
    # them, so the draft->verify gap is covered deterministically
    for live_checks in range(2, 12):
        free0 = batcher.cache.num_free_blocks + batcher.cache.reclaimable
        out = batcher.submit(
            p, max_new_tokens=24,
            deadline=_FlipDeadline(live_checks)).result(timeout=180)
        assert np.array_equal(out, ref[:, :out.shape[1]])  # a true prefix
        assert out.shape[1] < 24   # really shed mid-generation
        assert batcher.cache.num_free_blocks + batcher.cache.reclaimable \
            >= free0   # all blocks back (cache may retain prompt blocks)
    assert batcher.stats["spec_steps"] > 0


def test_spec_disabled_bit_identical_to_plain_decode(setup):
    """spec_decode=False is the pre-speculation scheduler: no verify
    steps, no drafts, and token-identical output to the dense engine."""
    cfg, engine, _ = setup
    eng = Engine(cfg, ServeConfig(cache_len=160, max_new_tokens=16,
                                  spec_decode=False), params=engine.params)
    batcher = PagedBatcher(eng, max_batch=2)
    try:
        for seed in (23, 29):
            p = _repetitive_prompt(cfg, seed)
            assert np.array_equal(batcher.generate(p, max_new_tokens=16),
                                  engine.generate(p, max_new_tokens=16))
        assert batcher.stats["spec_steps"] == 0
        assert batcher.stats["spec_proposed"] == 0
        assert batcher.stats["spec_accepted"] == 0
        # one decode step per emitted token batch: the serial loop
        assert batcher.stats["decode_steps"] >= 16
    finally:
        batcher.close()


def test_spec_with_prefix_cache_shared_blocks_cow(spec):
    """Speculative writes into a prefix-cache hit: the draft write range
    crossing a shared block copy-on-writes first, and the cached donor
    still replays correctly afterwards."""
    cfg, engine, batcher = spec
    # block-aligned repetitive prompt: full match on the second pass puts
    # the first (re-processed) token's write — and the speculative draft
    # writes behind it — at a shared-block boundary
    p = _repetitive_prompt(cfg, seed=31, motif_t=8, reps=4)  # 32 = 2 blocks
    ref = engine.generate(p, max_new_tokens=12)
    assert np.array_equal(batcher.generate(p, max_new_tokens=12), ref)
    cow0 = batcher.stats["cow_copies"]
    assert np.array_equal(batcher.generate(p, max_new_tokens=12), ref)
    assert batcher.stats["cow_copies"] > cow0
    assert np.array_equal(batcher.generate(p, max_new_tokens=12), ref)


def test_score_monotonic_sanity(setup):
    """Score of model-generated continuation >= score of random tokens."""
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    p = _prompt(cfg, t=8, seed=1)
    gen = inf.Generate({"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                        "max_new_tokens": 6})
    good = np.concatenate([p.reshape(-1),
                           np.asarray(gen["tokens"], np.uint32)])
    rng = np.random.default_rng(9)
    bad = np.concatenate([p.reshape(-1),
                          rng.integers(0, cfg.vocab_size, 6)
                          .astype(np.uint32)])
    s_good = inf.Score({"tokens": good, "batch": 1,
                        "seq_len": len(good)})["scores"][0]
    s_bad = inf.Score({"tokens": bad, "batch": 1,
                       "seq_len": len(bad)})["scores"][0]
    assert s_good >= s_bad
