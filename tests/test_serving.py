"""Serving integration: the paper's RPC protocol carrying a real model."""
import numpy as np
import pytest
import uuid

from repro.configs import get_config, reduced_config
from repro.core import wire
from repro.core.rpc import Channel, Deadline, RpcError, Status, connected_pair
from repro.serving import Engine, ServeConfig, build_server
from repro.serving.service import (GenerateRequest, GenerateResponse,
                                   InferenceService, ScoreResponse,
                                   TokenBatch, TokenChunk, TokenizeRequest)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen2-1.5b"))
    engine = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=8))
    server = build_server(engine)
    ct, st = connected_pair()
    server.serve_transport(st, blocking=False)
    ch = Channel(ct)
    yield cfg, engine, ch
    ch.close()


def _prompt(cfg, b=1, t=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (b, t)).astype(np.uint32)


def test_generate_unary(setup):
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    p = _prompt(cfg)
    res = inf.Generate({"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                        "max_new_tokens": 4})
    assert res["new_tokens"] == 4
    assert len(res["tokens"]) == 4


def test_generate_deterministic(setup):
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    p = _prompt(cfg)
    req = {"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
           "max_new_tokens": 4}
    a = list(inf.Generate(dict(req))["tokens"])
    b = list(inf.Generate(dict(req))["tokens"])
    assert a == b  # greedy decoding is deterministic


def test_stream_with_cursor_resume(setup):
    """Drop after 3 tokens; resume with cursor; identical total stream."""
    cfg, engine, ch = setup
    did = InferenceService.method("Stream").id
    p = _prompt(cfg)
    req = wire.encode(GenerateRequest,
                      {"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                       "max_new_tokens": 6})
    it = ch.call(did, req, server_stream=True)
    got, cursor = [], 0
    for item in it:
        chunk = wire.decode(TokenChunk, item.payload)
        got.extend(chunk["tokens"])
        cursor = item.cursor
        if chunk["index"] == 2:
            break
    it2 = ch.call(did, req, server_stream=True, cursor=cursor)
    rest = []
    for item in it2:
        rest.extend(wire.decode(TokenChunk, item.payload)["tokens"])
    full = [int(x) for x in got + rest]
    # reference: one-shot generate
    inf = ch.typed(InferenceService)
    ref = [int(x) for x in inf.Generate(
        {"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
         "max_new_tokens": 6})["tokens"]]
    assert full == ref


def test_batch_pipeline_tokenize_generate_score(setup):
    """The §7.3 flow on a real model: 3 dependent calls, 1 round trip."""
    cfg, engine, ch = setup
    tid = InferenceService.method("Tokenize").id
    gid = InferenceService.method("Generate").id
    sid = InferenceService.method("Score").id
    res = ch.batch([
        {"method_id": tid, "payload": wire.encode(
            TokenizeRequest, {"text": "hello bebop", "seq_len": 8})},
        # TokenBatch and GenerateRequest share tags 1-3, so the forwarded
        # result decodes as a valid GenerateRequest (schema-compatible
        # pipelining, like the paper's user->friends example)
        {"method_id": gid, "input_from": 0},
        {"method_id": sid, "input_from": 1},
    ])
    assert [r["status"] for r in res] == [Status.OK] * 3
    gen = wire.decode(GenerateResponse, res[1]["payload"])
    assert gen["new_tokens"] >= 1
    score = wire.decode(ScoreResponse, res[2]["payload"])
    assert len(score["scores"]) == 1
    assert np.isfinite(score["scores"][0])


def test_generate_deadline_shedding(setup):
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    p = _prompt(cfg)
    with pytest.raises(RpcError) as ei:
        inf.Generate({"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                      "max_new_tokens": 4}, deadline=Deadline.after(-1))
    assert ei.value.code == Status.DEADLINE_EXCEEDED


def test_long_generation_as_future(setup):
    cfg, engine, ch = setup
    gid = InferenceService.method("Generate").id
    p = _prompt(cfg)
    req = wire.encode(GenerateRequest,
                      {"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                       "max_new_tokens": 6})
    key = uuid.uuid4()
    h = ch.dispatch_future(gid, req, idempotency_key=key)
    results = list(ch.resolve_futures([h["id"]]))
    assert results[0]["status"] == Status.OK
    out = wire.decode(GenerateResponse, results[0]["payload"])
    assert out["new_tokens"] == 6
    # retried dispatch with same key: same handle
    h2 = ch.dispatch_future(gid, req, idempotency_key=key)
    assert h2["id"] == h["id"]


def test_score_monotonic_sanity(setup):
    """Score of model-generated continuation >= score of random tokens."""
    cfg, engine, ch = setup
    inf = ch.typed(InferenceService)
    p = _prompt(cfg, t=8, seed=1)
    gen = inf.Generate({"tokens": p.reshape(-1), "batch": 1, "seq_len": 8,
                        "max_new_tokens": 6})
    good = np.concatenate([p.reshape(-1),
                           np.asarray(gen["tokens"], np.uint32)])
    rng = np.random.default_rng(9)
    bad = np.concatenate([p.reshape(-1),
                          rng.integers(0, cfg.vocab_size, 6)
                          .astype(np.uint32)])
    s_good = inf.Score({"tokens": good, "batch": 1,
                        "seq_len": len(good)})["scores"][0]
    s_bad = inf.Score({"tokens": bad, "batch": 1,
                       "seq_len": len(bad)})["scores"][0]
    assert s_good >= s_bad
