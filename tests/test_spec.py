"""The n-gram drafter behind self-speculative decoding (serving/spec.py).

Pure host-side numpy: these tests pin the lookup semantics the scheduler
relies on — what gets proposed, from where in the history, and when the
drafter must stay silent (so the engine falls back to plain decode).
"""
import numpy as np

from repro.serving.spec import ngram_propose


def test_periodic_history_proposes_the_cycle():
    h = np.tile(np.array([3, 1, 4, 1, 5], np.int32), 4)
    d = ngram_propose(h, 5)
    assert d.tolist() == [3, 1, 4, 1, 5]


def test_single_token_fixed_point():
    # the classic greedy cycle: the model repeats one token forever
    h = np.array([9, 8] + [7] * 10, np.int32)
    d = ngram_propose(h, 4)
    assert d.tolist() == [7, 7, 7, 7]
    # a run too short for a full continuation still proposes what exists
    short = np.array([9, 8, 7, 7, 7, 7, 7, 7], np.int32)
    assert ngram_propose(short, 4).tolist() == [7]


def test_no_match_returns_empty():
    d = ngram_propose(np.arange(16, dtype=np.int32), 4)
    assert d.size == 0


def test_min_ngram_guards_spurious_unigram_matches():
    # 'suffix token seen once before' is NOT enough at min_n=2: on
    # near-random text a 1-gram hit is noise that would buy a full-width
    # verify step with ~zero acceptance
    h = np.array([5, 1, 2, 3, 4, 5], np.int32)
    assert ngram_propose(h, 4, min_n=2).size == 0
    assert ngram_propose(h, 4, min_n=1).tolist() == [1, 2, 3, 4]


def test_most_recent_occurrence_wins():
    # "1 2" occurs twice with different continuations; the newer one
    # (-> 9) must be proposed, not the older (-> 7)
    h = np.array([1, 2, 7, 7, 0, 1, 2, 9, 9, 0, 3, 1, 2], np.int32)
    assert ngram_propose(h, 2).tolist() == [9, 9]


def test_prefers_match_with_full_continuation():
    # on periodic text the newest match abuts the end of history; the
    # drafter must reach back one period to return a full-length draft
    h = np.tile(np.array([4, 2], np.int32), 6)
    assert ngram_propose(h, 4).tolist() == [4, 2, 4, 2]


def test_min_ngram_above_default_ceiling_still_drafts():
    # a min_n above the default max_n must raise the ceiling, not
    # silently empty the search range (speculation quietly off)
    h = np.tile(np.arange(6, dtype=np.int32), 4)
    assert ngram_propose(h, 4, min_n=6).tolist() == [0, 1, 2, 3]


def test_budget_clamps_proposal_length():
    h = np.tile(np.array([3, 1, 4, 1, 5], np.int32), 4)
    assert ngram_propose(h, 2).tolist() == [3, 1]
    assert ngram_propose(h, 0).size == 0


def test_short_history_never_crashes():
    assert ngram_propose(np.array([7], np.int32), 4).size == 0
    assert ngram_propose(np.array([7, 7], np.int32), 4, min_n=1).size == 0 \
        or ngram_propose(np.array([7, 7], np.int32), 4, min_n=1).tolist() \
        == [7]
    assert ngram_propose(np.zeros(0, np.int32), 4).size == 0
