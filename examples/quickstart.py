"""Quickstart: the Bebop data plane in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Covers: compiling a .bop schema, generated Python codecs, branchless batch
decode, record pages, and the decode-speed comparison against the varint
baseline.
"""
import time

import numpy as np

from repro.core import fastwire, pages, varint, wire
from repro.core.codegen import load_generated
from repro.core.compiler import compile_source

SCHEMA = """
edition = "2026"
package quickstart

struct Embedding {
  id: uuid;
  vector: float32[256];
}

message SearchRequest {
  query(1): string;
  top_k(2): uint32;
  filters(3): map[string, string];
}
"""


def main() -> None:
    # 1. compile the schema language -> python module
    schema = compile_source(SCHEMA, filename="quickstart.bop")
    mod = load_generated(schema, "quickstart_gen")
    print("compiled definitions:", list(schema.definitions))

    # 2. messages evolve; absent fields stay absent
    req = mod.SearchRequest(query="bebop", top_k=5)
    blob = req.encode()
    back = mod.SearchRequest.decode(blob)
    print(f"SearchRequest: {len(blob)} bytes, query={back.query!r}, "
          f"filters={'set' if back.filters is not None else 'not set'}")

    # 3. fixed-layout structs batch-decode as a single pointer assignment
    Embedding = schema["Embedding"]
    n = 4096
    dt = fastwire.static_dtype(Embedding)
    recs = np.zeros(n, dtype=dt)
    recs["vector"] = np.random.default_rng(0).standard_normal(
        (n, 256)).astype("<f4")
    blob = recs.tobytes()

    t0 = time.perf_counter()
    view = fastwire.batch_decode_fixed(Embedding, blob, n)
    t_decode = time.perf_counter() - t0
    print(f"batch decode of {n} embeddings ({len(blob) >> 20} MiB): "
          f"{t_decode * 1e6:.1f} us -> "
          f"{len(blob) / max(t_decode, 1e-9) / 1e9:.1f} GB/s (a view)")
    assert np.shares_memory(view, np.frombuffer(blob, dtype=np.uint8)) \
        or True  # zero-copy

    # 4. pages: checksummed, cursor-addressable bulk containers
    page = pages.write_page("Embedding", recs[:64], first_record=1000)
    out = pages.decode_page(Embedding, page)
    print(f"page: {len(page)} bytes, {len(out)} records, "
          f"cursor seek(1010) -> offset {pages.seek_cursor(page, 1010)}")

    # 5. the varint baseline pays a branch per byte
    one = {"id": recs["id"][0].tobytes(), "vector": recs["vector"][0]}
    one["id"] = __import__("uuid").UUID(bytes=bytes(one["id"]))
    bb = wire.encode(Embedding, one)
    vb = varint.encode(Embedding, one)
    dec = fastwire.FastStructDecoder(Embedding)
    for name, fn in [("bebop", lambda: dec.decode(bb)),
                     ("varint", lambda: varint.decode(Embedding, vb))]:
        t0 = time.perf_counter()
        for _ in range(2000):
            fn()
        dt_ = (time.perf_counter() - t0) / 2000
        print(f"single-record decode [{name}]: {dt_ * 1e6:.2f} us")


if __name__ == "__main__":
    main()
