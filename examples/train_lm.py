"""End-to-end training driver: Bebop data pages -> pipeline -> train loop
-> checkpoints -> restart.

Default: a ~20M-parameter qwen2-family model for 300 steps (a few minutes
on CPU).  `--hundred-m` trains a ~100M-parameter config for --steps steps
(the assignment's full driver; give it time or a TPU).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config, reduced_config
from repro.data import (BufferSource, DataConfig, Pipeline, synthetic_corpus,
                        write_example_pages)
from repro.train import OptimizerConfig, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = reduced_config(get_config("qwen2-1.5b"))
    if args.hundred_m:
        cfg = dataclasses.replace(
            cfg, name="qwen2-100m", num_layers=8, d_model=512, num_heads=8,
            num_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=32768)
    else:
        cfg = dataclasses.replace(
            cfg, name="qwen2-20m", num_layers=4, d_model=256, num_heads=4,
            num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=16384)
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")

    tokens = synthetic_corpus(args.seq_len, 4096, cfg.vocab_size, seed=0)
    buf = write_example_pages(args.seq_len, tokens, records_per_page=32)
    dc = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                    records_per_page=32)
    src = BufferSource(buf)
    pipe = Pipeline(dc, [src], len(src))

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro-ckpt-")
    trainer = Trainer(
        cfg,
        OptimizerConfig(lr=6e-4, warmup_steps=args.steps // 20,
                        total_steps=args.steps),
        TrainConfig(steps=args.steps, ckpt_every=max(args.steps // 4, 1),
                    ckpt_dir=ckpt_dir, log_every=max(args.steps // 15, 1)),
        data=iter(pipe))
    result = trainer.run()
    pipe.stop()
    for m in trainer.metrics:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"{m['tokens_per_s']:,.0f} tok/s")
    print(f"done: {result['status']} at step {result['step']}; "
          f"checkpoints in {ckpt_dir}")
    first, last = result["losses"][0][1], result["losses"][-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'no improvement'})")


if __name__ == "__main__":
    main()
