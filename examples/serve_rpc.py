"""Serving demo: every §7 protocol feature against a live model.

    PYTHONPATH=src python examples/serve_rpc.py

  1. unary Generate
  2. batch pipelining — Tokenize -> Generate -> Score in ONE round trip
  3. cursor-resumable token streaming (simulated disconnect)
  4. futures: dispatch long generation, push-based resolve, idempotency
  5. deadline propagation sheds expired work
  6. the wire->device page path
  7. ResilientChannel: the transport killed mid-InferStream, the client
     reconnects and resumes from its cursor — the caller sees one
     uninterrupted stream
  8. replica failover: two replicas behind the router front door, the
     one carrying an InferStream killed mid-flight — the router resumes
     on the survivor from its cursor watermark, transparently to a
     PLAIN client channel
  9. GenerationParams: seeded nucleus sampling and n=3 parallel
     candidates through the router front door — the fork shares prompt
     KV server-side, the seed makes it reproducible end to end, and
     candidate 0 is bit-identical to the n=1 answer
"""
import threading
import time
import uuid

import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import wire
from repro.core.rpc import (Channel, Deadline, ResilientChannel, RpcError,
                            Status, TcpTransport)
from repro.serving import (Engine, ServeConfig, build_server,
                           decode_token_page, encode_prompt_page)
from repro.serving.service import (GenerateRequest, GenerateResponse,
                                   InferenceService, InferRequest,
                                   ScoreResponse, TokenChunk,
                                   TokenizeRequest)


def main() -> None:
    cfg = reduced_config(get_config("gemma-2b"))
    engine = Engine(cfg, ServeConfig(cache_len=64, max_new_tokens=16))
    server = build_server(engine)
    host, port, lsock = server.listen_tcp()
    print(f"serving {cfg.name} at {host}:{port} over Bebop-RPC/TCP")
    ch = Channel(TcpTransport.connect(host, port))
    inf = ch.typed(InferenceService)

    prompt = np.arange(8, dtype=np.uint32) % cfg.vocab_size

    # 1. unary
    t0 = time.perf_counter()
    res = inf.Generate({"tokens": prompt, "batch": 1, "seq_len": 8,
                        "max_new_tokens": 6})
    print(f"[unary] {res['new_tokens']} tokens in "
          f"{1e3 * (time.perf_counter() - t0):.1f} ms: "
          f"{list(res['tokens'])}")

    # 2. batch pipelining: 3 dependent calls, one round trip (§7.3)
    tid = InferenceService.method("Tokenize").id
    gid = InferenceService.method("Generate").id
    sid = InferenceService.method("Score").id
    t0 = time.perf_counter()
    batch = ch.batch([
        {"method_id": tid, "payload": wire.encode(
            TokenizeRequest, {"text": "simplicity scales", "seq_len": 8})},
        {"method_id": gid, "input_from": 0},
        {"method_id": sid, "input_from": 1},
    ])
    dt = 1e3 * (time.perf_counter() - t0)
    score = wire.decode(ScoreResponse, batch[2]["payload"])["scores"][0]
    print(f"[batch] tokenize->generate->score in {dt:.1f} ms "
          f"(1 round trip); score={score:.3f}")

    # 3. cursor-resumable stream (§7.5): drop after 2 chunks, reconnect
    sid_stream = InferenceService.method("Stream").id
    req = wire.encode(GenerateRequest, {"tokens": prompt, "batch": 1,
                                        "seq_len": 8, "max_new_tokens": 6})
    got, cursor = [], 0
    for item in ch.call(sid_stream, req, server_stream=True):
        chunk = wire.decode(TokenChunk, item.payload)
        got.extend(int(x) for x in chunk["tokens"])
        cursor = item.cursor
        if chunk["index"] == 1:
            print(f"[stream] ...connection drops at cursor={cursor}")
            break
    for item in ch.call(sid_stream, req, server_stream=True, cursor=cursor):
        got.extend(int(x) for x in
                   wire.decode(TokenChunk, item.payload)["tokens"])
    print(f"[stream] resumed; full stream: {got}")

    # 4. futures (§7.6)
    key = uuid.uuid4()
    h = ch.dispatch_future(gid, req, idempotency_key=key)
    print(f"[future] dispatched {h['id']} (existing={h['existing']})")
    h2 = ch.dispatch_future(gid, req, idempotency_key=key)
    print(f"[future] retried with same key -> same handle: "
          f"{h2['id'] == h['id']}")
    for res in ch.resolve_futures([h["id"]]):
        out = wire.decode(GenerateResponse, res["payload"])
        print(f"[future] push-resolved: status={Status.name(res['status'])} "
              f"{out['new_tokens']} tokens")

    # 5. deadlines (§7.4)
    try:
        inf.Generate({"tokens": prompt, "batch": 1, "seq_len": 8,
                      "max_new_tokens": 4}, deadline=Deadline.after(-1))
    except RpcError as e:
        print(f"[deadline] expired work shed before prefill: "
              f"{Status.name(e.code)}")

    # 6. the wire->device path (§8): page in, device decode, page out
    page = encode_prompt_page(prompt.reshape(1, 8))
    t0 = time.perf_counter()
    res = inf.Infer({"page": page, "max_new_tokens": 6})
    out = decode_token_page(bytes(bytearray(res["page"])))
    print(f"[infer] page->device->page in "
          f"{1e3 * (time.perf_counter() - t0):.1f} ms: {list(out[0])} "
          f"(host parsed 0 tokens)")
    iid = InferenceService.method("Infer").id
    spid = InferenceService.method("ScorePage").id
    batch = ch.batch([
        {"method_id": iid, "payload": wire.encode(
            InferRequest, {"page": page, "max_new_tokens": 6})},
        {"method_id": spid, "input_from": 0},
    ])
    score = wire.decode(ScoreResponse, batch[1]["payload"])["scores"][0]
    print(f"[infer] Infer->ScorePage pipelined server-side; "
          f"score={score:.3f}")

    # 7. resilience: kill the transport mid-InferStream, watch the
    # ResilientChannel reconnect and resume from the last cursor
    from repro.serving.service import InferChunk
    live = []   # transports handed out, so the chaos thread can kill one

    def dial():
        t = TcpTransport.connect(host, port)
        live.append(t)
        return t

    rc = ResilientChannel(dial)
    isid = InferenceService.method("InferStream").id
    raw = wire.encode(InferRequest, {"page": page, "max_new_tokens": 6})
    seen = threading.Event()

    def killer():   # the "fault": yank the socket after the 2nd chunk
        seen.wait(timeout=30.0)
        live[0].close()
        print("[resilient] transport killed mid-stream...")

    threading.Thread(target=killer, daemon=True).start()
    tokens, resumed_at = [], None
    for item in rc.call(isid, raw, server_stream=True):
        chunk = wire.decode(InferChunk, item.payload)
        tokens.extend(int(t) for t in
                      decode_token_page(bytes(bytearray(chunk["page"])))[0])
        if item.cursor == 2:
            seen.set()          # arm the killer after two delivered chunks
        if rc.reconnects and resumed_at is None:
            resumed_at = item.cursor
    print(f"[resilient] stream survived: {len(tokens)} tokens "
          f"{tokens}, reconnects={rc.reconnects}, "
          f"resumed at cursor={resumed_at} (no gaps, no duplicates)")
    rc.close()

    # 8. replica failover: the fault moves from the wire to a whole
    # replica process.  Two engine replicas (own batchers + KV pools)
    # sit behind the router; the client is a PLAIN Channel — all the
    # resilience lives server-side in the front door.
    from repro.core.rpc import connected_pair
    from repro.serving import InProcessReplica
    from repro.serving.router import RouterConfig, build_router_server

    reps = [InProcessReplica(engine, f"replica{i}") for i in range(2)]
    rserver, router = build_router_server(
        reps, RouterConfig(health_interval_s=0, hedge=False))
    ct, st = connected_pair()
    rserver.serve_transport(st, blocking=False)
    rch = Channel(ct)

    tokens, failed_over_at = [], None
    for item in rch.call(isid, raw, server_stream=True):
        chunk = wire.decode(InferChunk, item.payload)
        tokens.extend(int(t) for t in
                      decode_token_page(bytes(bytearray(chunk["page"])))[0])
        if item.cursor == 2:
            owner = max(range(len(reps)),
                        key=lambda i: router.replicas[i].inflight)
            reps[owner].kill()
            print(f"[router] {reps[owner].name} killed mid-stream...")
        if router.stats["stream_failovers"] and failed_over_at is None:
            failed_over_at = item.cursor
    stats = router.collect_stats()
    print(f"[router] stream survived on the survivor: {len(tokens)} tokens "
          f"{tokens} (no gaps, no duplicates)")
    print(f"[router] failovers={stats['stream_failovers']:.0f} "
          f"resumed at cursor={failed_over_at}, "
          f"breaker_opens={stats['breaker_opens']:.0f}")
    rch.close()
    for rep in reps:
        rep.kill()

    # 9. sampled generation + n>1 candidates over the router: the
    # GenerationParams fields (temperature / top_k / top_p / seed / n)
    # ride the same Generate page; the router forwards them as raw
    # bytes, the engine prefills the prompt ONCE and forks it into 3
    # refcount-shared candidate rows that diverge copy-on-write
    reps = [InProcessReplica(engine, f"samp{i}") for i in range(2)]
    rserver, router = build_router_server(
        reps, RouterConfig(health_interval_s=0, hedge=False))
    ct, st = connected_pair()
    rserver.serve_transport(st, blocking=False)
    rch = Channel(ct)
    rinf = rch.typed(InferenceService)

    req = {"tokens": prompt, "batch": 1, "seq_len": 8,
           "max_new_tokens": 6, "temperature": 0.8, "top_p": 0.9,
           "seed": 7, "n": 3}
    res = rinf.Generate(dict(req))
    cands = np.asarray(res["tokens"]).reshape(res["batch"], -1)
    for i, row in enumerate(cands):
        print(f"[sample] candidate {i}: {row.tolist()}")
    again = rinf.Generate(dict(req))
    solo = rinf.Generate({**req, "n": 1})
    print(f"[sample] same seed, same tokens: "
          f"{list(res['tokens']) == list(again['tokens'])}; "
          f"candidate 0 == the n=1 answer: "
          f"{cands[0].tolist() == list(solo['tokens'])}")
    # the page-encoded Infer path runs the same request through the
    # PagedBatcher, which prefills the prompt ONCE and forks it into
    # refcount-shared candidate rows — and lands on the same tokens,
    # because the key schedule depends only on (seed, position, row)
    res_p = rinf.Infer({"page": page, "max_new_tokens": 6,
                        "temperature": 0.8, "top_p": 0.9, "seed": 7,
                        "n": 3})
    cands_p = decode_token_page(bytes(bytearray(res_p["page"])))
    gauges = [r.impl.batcher.collect_stats() for r in reps if r.impl]
    forks = sum(g["forks"] for g in gauges)
    sampled = sum(g["sampled_requests"] for g in gauges)
    print(f"[sample] paged Infer forked the prompt into "
          f"{forks:.0f} sibling rows instead of re-prefilling "
          f"(sampled_requests={sampled:.0f}); paged == dense: "
          f"{np.array_equal(np.asarray(cands_p, np.int32), cands)}")
    rch.close()
    router.close()
    for rep in reps:
        rep.kill()

    ch.close()
    lsock.close()
    print("done.")


if __name__ == "__main__":
    main()
