"""On-device deserialization: the paper's future-work item, running.

    PYTHONPATH=src python examples/device_decode.py

Training examples are Bebop structs packed into checksummed pages.  The
host never parses the payload: raw page bytes go to the device and the
bebop_decode kernel (interpret mode on CPU; pl.pallas_call on TPU)
materializes token tensors via branchless bitcasts.  We verify against the
host decoder and feed the decoded batch straight into a model loss.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core import pages
from repro.core.device import decode_page_device
from repro.data import (DataConfig, device_batches, example_layout,
                        synthetic_corpus, train_example_struct,
                        write_example_pages)
from repro.models import get_model


def main() -> None:
    seq = 64
    cfg = reduced_config(get_config("qwen2-1.5b"))
    tokens = synthetic_corpus(seq, 128, cfg.vocab_size, seed=7)
    buf = write_example_pages(seq, tokens, records_per_page=16)
    print(f"wrote {len(buf) >> 10} KiB of pages "
          f"({len(list(pages.iter_pages(buf)))} pages, CRC-checksummed)")

    layout = example_layout(seq)
    print(f"device layout: stride={layout.stride}B, columns="
          f"{[(c.name, c.offset, c.count, c.wire_dtype) for c in layout.columns]}")

    dc = DataConfig(seq_len=seq, global_batch=16, records_per_page=16)
    (payload, cursor) = next(device_batches(buf, dc))
    dev = jnp.asarray(payload)  # raw bytes on 'device'
    cols = decode_page_device(dev, layout, impl="pallas")  # Pallas kernel
    print(f"device-decoded tokens: {cols['tokens'].shape} "
          f"{cols['tokens'].dtype}; cursor={cursor}")

    # verify against host decode
    host = pages.decode_page(train_example_struct(seq), buf)
    assert np.array_equal(np.asarray(cols["tokens"])[:16],
                          host["tokens"][:16].astype("<i4"))
    print("device decode == host decode ✓")

    # feed straight into the model
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": cols["tokens"][:, :-1],
             "labels": cols["tokens"][:, 1:]}
    loss = jax.jit(model.loss)(params, batch)
    print(f"loss on device-decoded batch: {float(loss):.4f}")


if __name__ == "__main__":
    main()
